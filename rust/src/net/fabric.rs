//! The fabric: registered peer buffers + priced bulk-fetch operations,
//! generic over the [`Transport`] backend that physically carries them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::buffer::local::{ClassCount, SNAPSHOT_ENTRY_BYTES};
use crate::buffer::LocalBuffer;
use crate::config::TransportKind;
use crate::tensor::Sample;

use super::cost::CostModel;
use super::transport::{InprocTransport, TcpTransport, Transport};

/// Fabric-wide traffic counters (all workers).
#[derive(Debug, Default)]
pub struct FabricCounters {
    /// Bulk fetch RPCs issued (after consolidation: one per (src,dst) pair
    /// per sampling round). Identical across backends for the same run.
    pub rpcs: AtomicU64,
    /// Payload bytes the backend actually moved for bulk fetches: semantic
    /// row bytes (`4·d + 8` per row) on `inproc`, real frame bytes
    /// (payload + length prefixes + request) on `tcp`.
    pub bytes: AtomicU64,
    /// Metadata (snapshot) exchanges. Identical across backends.
    pub meta_rpcs: AtomicU64,
    /// Bytes the backend actually moved for metadata snapshots: the
    /// semantic entry size on `inproc`, real frame bytes on `tcp`.
    pub meta_bytes: AtomicU64,
    /// Virtual wire time accumulated, nanoseconds. Priced from the
    /// *semantic* payload on every backend, so projections are
    /// backend-independent.
    pub wire_ns: AtomicU64,
}

impl FabricCounters {
    /// `(rpcs, bytes, meta_rpcs, meta_bytes, wire)`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, Duration) {
        (
            self.rpcs.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.meta_rpcs.load(Ordering::Relaxed),
            self.meta_bytes.load(Ordering::Relaxed),
            Duration::from_nanos(self.wire_ns.load(Ordering::Relaxed)),
        )
    }
}

/// The distributed rehearsal buffer's communication substrate: N registered
/// local buffers behind a pluggable [`Transport`], plus the wire-cost model.
///
/// Policy lives here — RPC/byte accounting, virtual-time pricing, optional
/// delay emulation — while the transport owns mechanism (how bytes reach a
/// peer). Local fetches (`target == requester`) never touch the transport
/// and stay free on the wire, whichever backend is active.
pub struct Fabric {
    transport: Box<dyn Transport>,
    cost: CostModel,
    /// Sleep for the modeled wire time (wall-clock emulation mode).
    emulate_delays: bool,
    pub counters: FabricCounters,
}

impl Fabric {
    /// In-process fabric (the zero-copy default).
    pub fn new(buffers: Vec<Arc<LocalBuffer>>, cost: CostModel,
               emulate_delays: bool) -> Fabric {
        Fabric::with_transport(Box::new(InprocTransport::new(buffers)), cost,
                              emulate_delays)
    }

    /// Fabric over an explicit backend.
    pub fn with_transport(transport: Box<dyn Transport>, cost: CostModel,
                          emulate_delays: bool) -> Fabric {
        Fabric { transport, cost, emulate_delays, counters: FabricCounters::default() }
    }

    /// Fabric whose remote traffic rides real loopback TCP sockets (one
    /// listener thread per worker; see [`TcpTransport`]).
    pub fn over_tcp(buffers: Vec<Arc<LocalBuffer>>, cost: CostModel,
                    emulate_delays: bool) -> Result<Fabric> {
        Ok(Fabric::with_transport(Box::new(TcpTransport::new(buffers)?), cost,
                                  emulate_delays))
    }

    /// Build the backend selected by `kind`.
    pub fn for_kind(kind: TransportKind, buffers: Vec<Arc<LocalBuffer>>,
                    cost: CostModel, emulate_delays: bool) -> Result<Fabric> {
        match kind {
            TransportKind::Inproc => Ok(Fabric::new(buffers, cost, emulate_delays)),
            TransportKind::Tcp => Fabric::over_tcp(buffers, cost, emulate_delays),
        }
    }

    pub fn transport_kind(&self) -> TransportKind {
        self.transport.kind()
    }

    pub fn workers(&self) -> usize {
        self.transport.workers()
    }

    pub fn cost_model(&self) -> CostModel {
        self.cost
    }

    pub fn buffer(&self, worker: usize) -> &Arc<LocalBuffer> {
        self.transport.buffer(worker)
    }

    /// Tear down the transport's background machinery (listener and
    /// connection threads on `tcp`; a no-op on `inproc`). Idempotent. The
    /// trainer calls this after its workers are joined so no fabric thread
    /// outlives the run; dropping a TCP-backed fabric runs the same path.
    pub fn shutdown(&self) -> Result<()> {
        self.transport.shutdown()
    }

    /// Collect (worker, class, count) metadata from every peer — the
    /// planner's view of the global buffer. Charged as one small RPC per
    /// remote peer (the paper piggybacks this on its RPC layer). Fallible:
    /// a real backend can lose a peer mid-run.
    pub fn gather_counts(&self, requester: usize) -> Result<Vec<Vec<ClassCount>>> {
        let n = self.transport.workers();
        let mut all = Vec::with_capacity(n);
        let mut wire = Duration::ZERO;
        for target in 0..n {
            if target == requester {
                all.push(self.transport.buffer(target).snapshot_counts());
            } else {
                let (counts, moved) =
                    self.transport.remote_counts(requester, target)?;
                self.counters.meta_rpcs.fetch_add(1, Ordering::Relaxed);
                self.counters.meta_bytes.fetch_add(moved as u64,
                                                   Ordering::Relaxed);
                wire += self.cost.cost(counts.len() * SNAPSHOT_ENTRY_BYTES);
                all.push(counts);
            }
        }
        self.charge(wire);
        Ok(all)
    }

    /// One consolidated bulk fetch of rows `(class, idx)` from `target`'s
    /// buffer on behalf of `requester`. Local fetches are free on the wire.
    /// Returns the rows and the virtual wire cost charged.
    pub fn fetch_bulk(&self, requester: usize, target: usize,
                      picks: &[(u32, usize)]) -> Result<(Vec<Sample>, Duration)> {
        let n = self.transport.workers();
        if target >= n {
            bail!("bulk fetch by worker {requester} from unknown worker \
                   {target}: fabric has {n} workers");
        }
        if target == requester {
            // Local read: no RPC, no wire time, whichever backend.
            return Ok((self.transport.buffer(target).fetch_rows(picks)?,
                       Duration::ZERO));
        }
        if picks.is_empty() {
            return Ok((Vec::new(), Duration::ZERO));
        }
        let (rows, moved) = self.transport.remote_fetch(requester, target, picks)?;
        let semantic: usize = rows.iter().map(Sample::wire_bytes).sum();
        self.counters.rpcs.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(moved as u64, Ordering::Relaxed);
        let wire = self.cost.cost(semantic);
        self.charge(wire);
        Ok((rows, wire))
    }

    fn charge(&self, wire: Duration) {
        if wire.is_zero() {
            return;
        }
        self.counters
            .wire_ns
            .fetch_add(wire.as_nanos() as u64, Ordering::Relaxed);
        if self.emulate_delays {
            std::thread::sleep(wire);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buffers(n: usize, per_class: usize) -> Vec<Arc<LocalBuffer>> {
        crate::testkit::filled_buffers(n, per_class, 2)
    }

    fn fabric(n: usize, per_class: usize) -> Fabric {
        Fabric::new(buffers(n, per_class), CostModel::default(), false)
    }

    #[test]
    fn local_fetch_is_free_remote_is_priced() {
        let f = fabric(3, 5);
        let (rows, wire) = f.fetch_bulk(0, 0, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(wire.is_zero());
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 0);

        let (rows, wire) = f.fetch_bulk(0, 2, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|s| s.features[0] == 2.0), "rows from worker 2");
        assert!(wire > Duration::ZERO);
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 1);
        assert_eq!(f.counters.bytes.load(Ordering::Relaxed),
                   rows.iter().map(Sample::wire_bytes).sum::<usize>() as u64);
    }

    #[test]
    fn gather_counts_sees_every_peer() {
        let f = fabric(4, 3);
        let all = f.gather_counts(1).unwrap();
        assert_eq!(all.len(), 4);
        for counts in &all {
            assert_eq!(counts.len(), 4); // 4 classes each
            assert!(counts.iter().all(|&(_, n)| n == 3));
        }
        // 3 remote metadata RPCs charged
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn unknown_worker_error_reports_context() {
        let f = fabric(2, 1);
        let err = f.fetch_bulk(0, 7, &[(0, 0)]).unwrap_err().to_string();
        assert!(err.contains("worker 0"), "missing requester: {err}");
        assert!(err.contains("unknown worker 7"), "missing target: {err}");
        assert!(err.contains("2 workers"), "missing worker count: {err}");
    }

    #[test]
    fn wire_time_accumulates() {
        let f = fabric(2, 4);
        let before = f.counters.wire_ns.load(Ordering::Relaxed);
        f.fetch_bulk(0, 1, &[(0, 0), (1, 1), (2, 2)]).unwrap();
        assert!(f.counters.wire_ns.load(Ordering::Relaxed) > before);
    }

    #[test]
    fn tcp_backend_serves_the_same_rpcs() {
        let f = Fabric::over_tcp(buffers(3, 5), CostModel::default(), false)
            .unwrap();
        assert_eq!(f.transport_kind(), TransportKind::Tcp);
        let all = f.gather_counts(0).unwrap();
        assert_eq!(all.len(), 3);
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 2);

        let (rows, wire) = f.fetch_bulk(0, 1, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|s| s.features[0] == 1.0));
        assert!(wire > Duration::ZERO);
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 1);
        // actual frame bytes exceed the semantic payload (framing overhead)
        let semantic: u64 = rows.iter().map(Sample::wire_bytes).sum::<usize>() as u64;
        assert!(f.counters.bytes.load(Ordering::Relaxed) > semantic);
        f.shutdown().unwrap();
    }
}
