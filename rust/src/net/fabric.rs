//! The fabric: registered peer buffers + priced bulk-fetch operations.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::buffer::LocalBuffer;
use crate::tensor::Sample;

use super::cost::CostModel;

/// Fabric-wide traffic counters (all workers).
#[derive(Debug, Default)]
pub struct FabricCounters {
    /// Bulk fetch RPCs issued (after consolidation: one per (src,dst) pair
    /// per sampling round).
    pub rpcs: AtomicU64,
    /// Payload bytes moved over the simulated wire.
    pub bytes: AtomicU64,
    /// Metadata (snapshot) exchanges.
    pub meta_rpcs: AtomicU64,
    /// Virtual wire time accumulated, nanoseconds.
    pub wire_ns: AtomicU64,
}

impl FabricCounters {
    pub fn snapshot(&self) -> (u64, u64, u64, Duration) {
        (
            self.rpcs.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.meta_rpcs.load(Ordering::Relaxed),
            Duration::from_nanos(self.wire_ns.load(Ordering::Relaxed)),
        )
    }
}

/// The distributed rehearsal buffer's communication substrate: N registered
/// local buffers plus the wire-cost model.
pub struct Fabric {
    buffers: Vec<Arc<LocalBuffer>>,
    cost: CostModel,
    /// Sleep for the modeled wire time (wall-clock emulation mode).
    emulate_delays: bool,
    pub counters: FabricCounters,
}

impl Fabric {
    pub fn new(buffers: Vec<Arc<LocalBuffer>>, cost: CostModel,
               emulate_delays: bool) -> Fabric {
        Fabric { buffers, cost, emulate_delays, counters: FabricCounters::default() }
    }

    pub fn workers(&self) -> usize {
        self.buffers.len()
    }

    pub fn cost_model(&self) -> CostModel {
        self.cost
    }

    pub fn buffer(&self, worker: usize) -> &Arc<LocalBuffer> {
        &self.buffers[worker]
    }

    /// Collect (worker, class, count) metadata from every peer — the
    /// planner's view of the global buffer. Charged as one small RPC per
    /// remote peer (the paper piggybacks this on its RPC layer).
    pub fn gather_counts(&self, requester: usize) -> Vec<Vec<(u32, usize)>> {
        let mut all = Vec::with_capacity(self.buffers.len());
        let mut wire = Duration::ZERO;
        for (n, buf) in self.buffers.iter().enumerate() {
            let counts = buf.snapshot_counts();
            if n != requester {
                self.counters.meta_rpcs.fetch_add(1, Ordering::Relaxed);
                wire += self.cost.cost(buf.snapshot_wire_bytes());
            }
            all.push(counts);
        }
        self.charge(wire);
        all
    }

    /// One consolidated bulk fetch of rows `(class, idx)` from `target`'s
    /// buffer on behalf of `requester`. Local fetches are free on the wire.
    /// Returns the rows and the virtual wire cost charged.
    pub fn fetch_bulk(&self, requester: usize, target: usize,
                      picks: &[(u32, usize)]) -> Result<(Vec<Sample>, Duration)> {
        if target >= self.buffers.len() {
            bail!("fetch from unknown worker {target}");
        }
        let rows = self.buffers[target].fetch_rows(picks);
        let mut wire = Duration::ZERO;
        if target != requester && !rows.is_empty() {
            let bytes: usize = rows.iter().map(Sample::wire_bytes).sum();
            self.counters.rpcs.fetch_add(1, Ordering::Relaxed);
            self.counters.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            wire = self.cost.cost(bytes);
            self.charge(wire);
        }
        Ok((rows, wire))
    }

    fn charge(&self, wire: Duration) {
        if wire.is_zero() {
            return;
        }
        self.counters
            .wire_ns
            .fetch_add(wire.as_nanos() as u64, Ordering::Relaxed);
        if self.emulate_delays {
            std::thread::sleep(wire);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvictionPolicy;

    fn fabric(n: usize, per_class: usize) -> Fabric {
        let buffers: Vec<Arc<LocalBuffer>> = (0..n)
            .map(|w| {
                let b = LocalBuffer::new(100, EvictionPolicy::Random, w as u64);
                for class in 0..4u32 {
                    for i in 0..per_class {
                        b.insert(Sample::new(class, vec![w as f32, i as f32]));
                    }
                }
                Arc::new(b)
            })
            .collect();
        Fabric::new(buffers, CostModel::default(), false)
    }

    #[test]
    fn local_fetch_is_free_remote_is_priced() {
        let f = fabric(3, 5);
        let (rows, wire) = f.fetch_bulk(0, 0, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(wire.is_zero());
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 0);

        let (rows, wire) = f.fetch_bulk(0, 2, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|s| s.features[0] == 2.0), "rows from worker 2");
        assert!(wire > Duration::ZERO);
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 1);
        assert_eq!(f.counters.bytes.load(Ordering::Relaxed),
                   rows.iter().map(Sample::wire_bytes).sum::<usize>() as u64);
    }

    #[test]
    fn gather_counts_sees_every_peer() {
        let f = fabric(4, 3);
        let all = f.gather_counts(1);
        assert_eq!(all.len(), 4);
        for counts in &all {
            assert_eq!(counts.len(), 4); // 4 classes each
            assert!(counts.iter().all(|&(_, n)| n == 3));
        }
        // 3 remote metadata RPCs charged
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn unknown_worker_errors() {
        let f = fabric(2, 1);
        assert!(f.fetch_bulk(0, 7, &[(0, 0)]).is_err());
    }

    #[test]
    fn wire_time_accumulates() {
        let f = fabric(2, 4);
        let before = f.counters.wire_ns.load(Ordering::Relaxed);
        f.fetch_bulk(0, 1, &[(0, 0), (1, 1), (2, 2)]).unwrap();
        assert!(f.counters.wire_ns.load(Ordering::Relaxed) > before);
    }
}
