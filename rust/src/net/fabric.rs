//! The fabric: registered peer buffers + priced bulk-fetch operations,
//! generic over the [`Transport`] backend that physically carries them.
//!
//! # Bounded-staleness metadata plane
//!
//! The paper's planner needs each peer's (class, count) snapshot every
//! iteration; issuing `N−1` metadata RPCs per worker-iteration is O(N²)
//! per global step and dominates at 64–128 workers. The fabric therefore
//! keeps a **per-(requester, target) counts cache** refreshed two ways:
//!
//! 1. **Cadence** — `meta_refresh_rounds = k` (config `[cluster]`,
//!    default 1): a cached entry older than `k` of the requester's
//!    `gather_counts` rounds is re-fetched with a real metadata RPC. At
//!    `k = 1` every round refreshes, bit-identical to the uncached
//!    behavior; at `k > 1` amortized metadata RPCs drop to `≤ (N−1)/k`
//!    per worker-iteration.
//! 2. **Piggyback** — every remote `fetch_bulk` response carries the
//!    target's current snapshot (see [`Transport::remote_fetch`]), which
//!    resets that entry's staleness clock for free.
//!
//! Plans built from cached counts are therefore at most `k` rounds stale;
//! the stale-pick tolerance in `LocalBuffer::fetch_rows` (modulo
//! remapping) absorbs the residual snapshot/insert race. Counters stay
//! honest: `meta_rpcs`/`meta_bytes` count only frames actually exchanged
//! (cache hits and piggybacks add none), while the piggybacked snapshot is
//! *priced* into virtual wire time at the semantic
//! [`SNAPSHOT_ENTRY_BYTES`] rate on every backend.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::buffer::local::{ClassCount, SNAPSHOT_ENTRY_BYTES};
use crate::buffer::LocalBuffer;
use crate::cluster::membership::{Membership, DEFAULT_RETRY_BUDGET};
use crate::config::TransportKind;
use crate::tensor::Sample;

use super::cost::CostModel;
use super::transport::{FaultPlan, FaultyTransport, InprocTransport,
                       TcpTransport, Transport};

/// Fabric-wide traffic counters (all workers).
#[derive(Debug, Default)]
pub struct FabricCounters {
    /// Bulk fetch RPCs issued (after consolidation: one per (src,dst) pair
    /// per sampling round). Identical across backends for the same run.
    pub rpcs: AtomicU64,
    /// Payload bytes the backend actually moved for bulk fetches: semantic
    /// row bytes (`4·d + 8` per row) on `inproc`, real frame bytes
    /// (payload + length prefixes + request) on `tcp`.
    pub bytes: AtomicU64,
    /// Metadata (snapshot) exchanges. Identical across backends.
    pub meta_rpcs: AtomicU64,
    /// Bytes the backend actually moved for metadata snapshots: the
    /// semantic entry size on `inproc`, real frame bytes on `tcp`.
    pub meta_bytes: AtomicU64,
    /// Virtual wire time accumulated, nanoseconds. Priced from the
    /// *semantic* payload on every backend, so projections are
    /// backend-independent.
    pub wire_ns: AtomicU64,
    /// Remote exchanges that degraded instead of failing the run (elastic
    /// mode, PR 9): a peer RPC errored or targeted a committed-lost peer,
    /// and the fabric served what it still could — empty rows, stale or
    /// empty counts. Never incremented with `elastic = false`, where the
    /// same errors poison the run.
    pub degraded_fetches: AtomicU64,
}

impl FabricCounters {
    /// `(rpcs, bytes, meta_rpcs, meta_bytes, wire)`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, Duration) {
        (
            self.rpcs.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.meta_rpcs.load(Ordering::Relaxed),
            self.meta_bytes.load(Ordering::Relaxed),
            Duration::from_nanos(self.wire_ns.load(Ordering::Relaxed)),
        )
    }

    pub fn degraded(&self) -> u64 {
        self.degraded_fetches.load(Ordering::Relaxed)
    }

    /// All six tallies in checkpoint order (`ckpt::FabricTallies`):
    /// `[rpcs, bytes, meta_rpcs, meta_bytes, wire_ns, degraded_fetches]`.
    pub fn export(&self) -> [u64; 6] {
        [
            self.rpcs.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.meta_rpcs.load(Ordering::Relaxed),
            self.meta_bytes.load(Ordering::Relaxed),
            self.wire_ns.load(Ordering::Relaxed),
            self.degraded_fetches.load(Ordering::Relaxed),
        ]
    }

    /// Restore the tallies from a checkpoint (same order as `export`).
    pub fn restore(&self, t: [u64; 6]) {
        self.rpcs.store(t[0], Ordering::Relaxed);
        self.bytes.store(t[1], Ordering::Relaxed);
        self.meta_rpcs.store(t[2], Ordering::Relaxed);
        self.meta_bytes.store(t[3], Ordering::Relaxed);
        self.wire_ns.store(t[4], Ordering::Relaxed);
        self.degraded_fetches.store(t[5], Ordering::Relaxed);
    }
}

/// One cached peer snapshot in the metadata plane.
#[derive(Debug, Default)]
struct PeerCounts {
    counts: Vec<ClassCount>,
    /// Requester round (see `MetaPlane::rounds`) at which this entry was
    /// last refreshed — by metadata RPC or by a piggybacked fetch response.
    refreshed_round: u64,
    /// False until the first refresh; an invalid entry always RPCs.
    valid: bool,
}

/// The bounded-staleness counts cache: one entry per (requester, target)
/// pair, plus a per-requester round counter advanced by `gather_counts`.
/// Entries are only ever touched by their requester's own threads (the
/// foreground worker or its background engine, which serialize), so the
/// per-entry mutexes are uncontended in practice.
struct MetaPlane {
    /// Refresh cadence `k` in requester rounds; 1 = refresh every round.
    refresh_rounds: u64,
    /// Per-requester `gather_counts` round counter.
    rounds: Vec<AtomicU64>,
    /// `cache[requester * n + target]`.
    cache: Vec<Mutex<PeerCounts>>,
}

impl MetaPlane {
    fn new(workers: usize) -> MetaPlane {
        MetaPlane {
            refresh_rounds: 1,
            rounds: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            cache: (0..workers * workers)
                .map(|_| Mutex::new(PeerCounts::default()))
                .collect(),
        }
    }
}

/// The distributed rehearsal buffer's communication substrate: N registered
/// local buffers behind a pluggable [`Transport`], plus the wire-cost model.
///
/// Policy lives here — RPC/byte accounting, virtual-time pricing, the
/// bounded-staleness metadata cache, optional delay emulation — while the
/// transport owns mechanism (how bytes reach a peer). Local fetches
/// (`target == requester`) never touch the transport and stay free on the
/// wire, whichever backend is active.
pub struct Fabric {
    transport: Box<dyn Transport>,
    cost: CostModel,
    /// Sleep for the modeled wire time (wall-clock emulation mode).
    emulate_delays: bool,
    meta: MetaPlane,
    pub counters: FabricCounters,
    /// Elastic fault domain (PR 9, `[cluster] elastic`): when set, a
    /// failed peer exchange degrades (strike + fallback + counted in
    /// `degraded_fetches`) instead of erroring the round; committed-lost
    /// peers are skipped. Default `false` — errors poison as before.
    elastic: bool,
    /// Peer liveness, shared with anyone holding the fabric (the trainer
    /// reads it at epoch boundaries to commit losses).
    membership: Arc<Membership>,
}

impl Fabric {
    /// In-process fabric (the zero-copy default).
    pub fn new(buffers: Vec<Arc<LocalBuffer>>, cost: CostModel,
               emulate_delays: bool) -> Fabric {
        Fabric::with_transport(Box::new(InprocTransport::new(buffers)), cost,
                              emulate_delays)
    }

    /// Fabric over an explicit backend.
    pub fn with_transport(transport: Box<dyn Transport>, cost: CostModel,
                          emulate_delays: bool) -> Fabric {
        let meta = MetaPlane::new(transport.workers());
        let membership = Arc::new(Membership::new(transport.workers(),
                                                  DEFAULT_RETRY_BUDGET));
        Fabric { transport, cost, emulate_delays, meta,
                 counters: FabricCounters::default(),
                 elastic: false, membership }
    }

    /// Enable the elastic fault domain: failed peer exchanges degrade
    /// (recorded against [`Membership`], counted in `degraded_fetches`,
    /// served with whatever is still reachable) instead of erroring the
    /// round, and committed-lost peers are skipped entirely.
    pub fn with_elastic(mut self, on: bool) -> Fabric {
        self.elastic = on;
        self
    }

    pub fn is_elastic(&self) -> bool {
        self.elastic
    }

    /// The fabric's peer-liveness view (strike counts, committed losses).
    pub fn membership(&self) -> &Arc<Membership> {
        &self.membership
    }

    /// Epoch-boundary commit of pending peer losses (see
    /// [`Membership::advance_epoch`]); returns the newly lost peers.
    pub fn advance_membership_epoch(&self) -> Option<Vec<usize>> {
        self.membership.advance_epoch()
    }

    /// Wrap the transport in a seeded [`FaultyTransport`] (test-only fault
    /// injection, `[cluster] fault_plan`). The wrapper is deterministic for
    /// a fixed plan + seed; counters and the metadata plane carry over.
    pub fn with_fault_injection(self, plan: FaultPlan, seed: u64) -> Fabric {
        let Fabric { transport, cost, emulate_delays, meta, counters,
                     elastic, membership } = self;
        Fabric {
            transport: Box::new(FaultyTransport::new(transport, plan, seed)),
            cost, emulate_delays, meta, counters, elastic, membership,
        }
    }

    /// Set the metadata refresh cadence `k` (rounds a cached peer snapshot
    /// may serve the planner before a real metadata RPC re-fetches it).
    /// `k = 1` (the default) refreshes every round — bit-identical plans to
    /// the uncached fabric; `0` is clamped to 1.
    pub fn with_meta_refresh_rounds(mut self, k: usize) -> Fabric {
        self.meta.refresh_rounds = (k as u64).max(1);
        self
    }

    /// The configured metadata refresh cadence.
    pub fn meta_refresh_rounds(&self) -> usize {
        self.meta.refresh_rounds as usize
    }

    /// Fabric whose remote traffic rides real loopback TCP sockets (one
    /// listener thread per worker; see [`TcpTransport`]). Backoff jitter
    /// uses seed 0; runs that need replayable retry timing go through
    /// [`Fabric::for_kind_seeded`].
    pub fn over_tcp(buffers: Vec<Arc<LocalBuffer>>, cost: CostModel,
                    emulate_delays: bool) -> Result<Fabric> {
        Ok(Fabric::with_transport(Box::new(TcpTransport::new(buffers)?), cost,
                                  emulate_delays))
    }

    /// Build the backend selected by `kind` (backoff jitter seed 0).
    pub fn for_kind(kind: TransportKind, buffers: Vec<Arc<LocalBuffer>>,
                    cost: CostModel, emulate_delays: bool) -> Result<Fabric> {
        Fabric::for_kind_seeded(kind, buffers, cost, emulate_delays, 0)
    }

    /// Build the backend selected by `kind`, threading the experiment seed
    /// into the TCP retry-backoff jitter stream
    /// ([`crate::util::rng::SeedDomain::TcpBackoff`]) so chaos runs replay
    /// their retry timing. `inproc` has no retries; the seed is unused.
    pub fn for_kind_seeded(kind: TransportKind, buffers: Vec<Arc<LocalBuffer>>,
                           cost: CostModel, emulate_delays: bool, seed: u64)
                           -> Result<Fabric> {
        match kind {
            TransportKind::Inproc => Ok(Fabric::new(buffers, cost, emulate_delays)),
            TransportKind::Tcp => Ok(Fabric::with_transport(
                Box::new(TcpTransport::with_seed(buffers, seed)?), cost,
                emulate_delays)),
        }
    }

    pub fn transport_kind(&self) -> TransportKind {
        self.transport.kind()
    }

    pub fn workers(&self) -> usize {
        self.transport.workers()
    }

    pub fn cost_model(&self) -> CostModel {
        self.cost
    }

    pub fn buffer(&self, worker: usize) -> &Arc<LocalBuffer> {
        self.transport.buffer(worker)
    }

    /// Tear down the transport's background machinery (listener and
    /// connection threads on `tcp`; a no-op on `inproc`). Idempotent. The
    /// trainer calls this after its workers are joined so no fabric thread
    /// outlives the run; dropping a TCP-backed fabric runs the same path.
    pub fn shutdown(&self) -> Result<()> {
        self.transport.shutdown()
    }

    /// Collect (worker, class, count) metadata from every peer — the
    /// planner's view of the global buffer. One `gather_counts` call is one
    /// *round* of the requester's metadata clock: a peer entry refreshed
    /// (by RPC or a piggybacked fetch) within the last `meta_refresh_rounds`
    /// rounds is served from the cache — no RPC, no wire charge — so the
    /// counts the planner sees are at most `k` rounds stale. Fallible: a
    /// real backend can lose a peer mid-run.
    pub fn gather_counts(&self, requester: usize) -> Result<Vec<Vec<ClassCount>>> {
        let n = self.transport.workers();
        let k = self.meta.refresh_rounds;
        let round = self.meta.rounds[requester].fetch_add(1, Ordering::Relaxed);
        let mut all = Vec::with_capacity(n);
        let mut wire = Duration::ZERO;
        for target in 0..n {
            if target == requester {
                // The local snapshot is always live and always free.
                all.push(self.transport.buffer(target).snapshot_counts());
                continue;
            }
            if k <= 1 {
                // Uncached fast path: k = 1 bypasses the cache entirely —
                // bit-identical plans to the pre-cache fabric (even for
                // call patterns where a fetch preceded the first gather)
                // and no per-peer lock/clone on the default hot path.
                let (counts, moved) =
                    match self.counts_exchange(requester, target)? {
                        Some(ok) => ok,
                        None => {
                            // degraded/lost peer: the planner sees an
                            // empty buffer there and plans around it
                            all.push(Vec::new());
                            continue;
                        }
                    };
                self.counters.meta_rpcs.fetch_add(1, Ordering::Relaxed);
                self.counters.meta_bytes.fetch_add(moved as u64,
                                                   Ordering::Relaxed);
                wire += self.cost.cost(counts.len() * SNAPSHOT_ENTRY_BYTES);
                all.push(counts);
                continue;
            }
            let mut entry = self.meta.cache[requester * n + target]
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            let fresh = entry.valid
                && round.saturating_sub(entry.refreshed_round) < k;
            if !fresh {
                match self.counts_exchange(requester, target)? {
                    Some((counts, moved)) => {
                        self.counters.meta_rpcs.fetch_add(1, Ordering::Relaxed);
                        self.counters.meta_bytes.fetch_add(moved as u64,
                                                           Ordering::Relaxed);
                        wire += self.cost
                            .cost(counts.len() * SNAPSHOT_ENTRY_BYTES);
                        entry.counts = counts;
                        entry.refreshed_round = round;
                        entry.valid = true;
                    }
                    // Degraded: serve the stale cached view if there is
                    // one (better than pretending the peer is empty while
                    // it may come back before the loss commits); an
                    // invalid entry serves its empty default.
                    None => {}
                }
            }
            all.push(entry.counts.clone());
        }
        self.charge(wire);
        Ok(all)
    }

    /// One consolidated bulk fetch of rows `(class, idx)` from `target`'s
    /// buffer on behalf of `requester`. Local fetches are free on the wire.
    /// The response piggybacks the target's current snapshot, which
    /// refreshes the requester's cached view of that peer (no metadata
    /// frame spent) and is priced into the virtual wire time at the
    /// semantic [`SNAPSHOT_ENTRY_BYTES`] rate on every backend.
    /// Returns the rows and the virtual wire cost charged.
    pub fn fetch_bulk(&self, requester: usize, target: usize,
                      picks: &[(u32, usize)]) -> Result<(Vec<Sample>, Duration)> {
        let n = self.transport.workers();
        if target >= n {
            bail!("bulk fetch by worker {requester} from unknown worker \
                   {target}: fabric has {n} workers");
        }
        if target == requester {
            // Local read: no RPC, no wire time, whichever backend.
            return Ok((self.transport.buffer(target).fetch_rows(picks)?,
                       Duration::ZERO));
        }
        if picks.is_empty() {
            return Ok((Vec::new(), Duration::ZERO));
        }
        if self.elastic && !self.membership.is_alive(target) {
            // Committed loss: the planner's view of this peer is already
            // empty, so picks naming it are a plan/commit race — serve the
            // local-only fallback (no rows) rather than probe a dead peer.
            return Ok((Vec::new(), Duration::ZERO));
        }
        let (rows, peer_counts, moved) =
            match self.transport.remote_fetch(requester, target, picks) {
                Ok(ok) => {
                    if self.elastic {
                        self.membership.record_success(target);
                    }
                    ok
                }
                Err(_) if self.elastic => {
                    // Degraded window: strike the peer, count the
                    // fallback, and let the round continue with the rows
                    // it got from everyone else (partial representative
                    // sets already train augmented).
                    self.membership.record_failure(target);
                    self.counters.degraded_fetches
                        .fetch_add(1, Ordering::Relaxed);
                    return Ok((Vec::new(), Duration::ZERO));
                }
                Err(e) => return Err(e),
            };
        let semantic: usize = rows.iter().map(Sample::wire_bytes).sum::<usize>()
            + peer_counts.len() * SNAPSHOT_ENTRY_BYTES;
        self.counters.rpcs.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(moved as u64, Ordering::Relaxed);
        if self.meta.refresh_rounds > 1 {
            // Opportunistic refresh: stamp with the requester's *current*
            // round (rounds[r] − 1, since gather_counts pre-increments), so
            // a peer fetched from this round needs no metadata RPC for the
            // next k rounds. Skipped at k = 1, where gather_counts bypasses
            // the cache and would never read the entry.
            let round = self.meta.rounds[requester]
                .load(Ordering::Relaxed)
                .saturating_sub(1);
            let mut entry = self.meta.cache[requester * n + target]
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            entry.counts = peer_counts;
            entry.refreshed_round = round;
            entry.valid = true;
        }
        let wire = self.cost.cost(semantic);
        self.charge(wire);
        Ok((rows, wire))
    }

    /// Elastic-aware metadata exchange: `Ok(Some(..))` on success,
    /// `Ok(None)` when elastic mode absorbed a lost/failing peer (live
    /// failures strike the peer and count as degraded; committed losses
    /// are skipped silently — the membership already agreed on them),
    /// `Err` when `elastic = false` (the error poisons the round,
    /// exactly the pre-PR-9 behavior).
    fn counts_exchange(&self, requester: usize, target: usize)
                       -> Result<Option<(Vec<ClassCount>, usize)>> {
        if self.elastic && !self.membership.is_alive(target) {
            return Ok(None);
        }
        match self.transport.remote_counts(requester, target) {
            Ok(ok) => {
                if self.elastic {
                    self.membership.record_success(target);
                }
                Ok(Some(ok))
            }
            Err(_) if self.elastic => {
                self.membership.record_failure(target);
                self.counters.degraded_fetches.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }

    fn charge(&self, wire: Duration) {
        if wire.is_zero() {
            return;
        }
        self.counters
            .wire_ns
            .fetch_add(wire.as_nanos() as u64, Ordering::Relaxed);
        if self.emulate_delays {
            std::thread::sleep(wire);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buffers(n: usize, per_class: usize) -> Vec<Arc<LocalBuffer>> {
        crate::testkit::filled_buffers(n, per_class, 2)
    }

    fn fabric(n: usize, per_class: usize) -> Fabric {
        Fabric::new(buffers(n, per_class), CostModel::default(), false)
    }

    #[test]
    fn local_fetch_is_free_remote_is_priced() {
        let f = fabric(3, 5);
        let (rows, wire) = f.fetch_bulk(0, 0, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(wire.is_zero());
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 0);

        let (rows, wire) = f.fetch_bulk(0, 2, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|s| s.features[0] == 2.0), "rows from worker 2");
        assert!(wire > Duration::ZERO);
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 1);
        // inproc bytes = semantic rows + the piggybacked snapshot (4
        // classes × SNAPSHOT_ENTRY_BYTES) that rides every remote fetch.
        assert_eq!(f.counters.bytes.load(Ordering::Relaxed),
                   (rows.iter().map(Sample::wire_bytes).sum::<usize>()
                    + 4 * SNAPSHOT_ENTRY_BYTES) as u64);
        // the piggyback is priced, not separately framed
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 0);
        assert_eq!(f.counters.meta_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn gather_counts_sees_every_peer() {
        let f = fabric(4, 3);
        let all = f.gather_counts(1).unwrap();
        assert_eq!(all.len(), 4);
        for counts in &all {
            assert_eq!(counts.len(), 4); // 4 classes each
            assert!(counts.iter().all(|&(_, n)| n == 3));
        }
        // 3 remote metadata RPCs charged
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn unknown_worker_error_reports_context() {
        let f = fabric(2, 1);
        let err = f.fetch_bulk(0, 7, &[(0, 0)]).unwrap_err().to_string();
        assert!(err.contains("worker 0"), "missing requester: {err}");
        assert!(err.contains("unknown worker 7"), "missing target: {err}");
        assert!(err.contains("2 workers"), "missing worker count: {err}");
    }

    #[test]
    fn wire_time_accumulates() {
        let f = fabric(2, 4);
        let before = f.counters.wire_ns.load(Ordering::Relaxed);
        f.fetch_bulk(0, 1, &[(0, 0), (1, 1), (2, 2)]).unwrap();
        assert!(f.counters.wire_ns.load(Ordering::Relaxed) > before);
    }

    #[test]
    fn cadence_amortizes_meta_rpcs() {
        // k = 3 over 9 rounds: each of the 3 remote peers is RPC-refreshed
        // at rounds 0, 3, 6 → 9 meta RPCs instead of 27.
        let f = fabric(4, 3).with_meta_refresh_rounds(3);
        for _ in 0..9 {
            let all = f.gather_counts(1).unwrap();
            assert_eq!(all.len(), 4);
        }
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 9,
                   "3 peers x ceil(9/3) refresh rounds");
    }

    #[test]
    fn cached_counts_are_at_most_k_rounds_stale() {
        let f = fabric(2, 2).with_meta_refresh_rounds(4);
        let before = f.gather_counts(0).unwrap();
        assert_eq!(before[1], vec![(0, 2), (1, 2), (2, 2), (3, 2)]);
        // peer 1 grows a class; rounds 1..3 still serve the cached view
        f.buffer(1).insert(Sample::new(0, vec![9.0, 9.0]));
        for _ in 1..4 {
            let stale = f.gather_counts(0).unwrap();
            assert_eq!(stale[1], before[1], "cache must serve within k rounds");
        }
        // round 4 crosses the cadence: the refresh sees the insert
        let fresh = f.gather_counts(0).unwrap();
        assert_eq!(fresh[1][0], (0, 3), "staleness exceeded k without refresh");
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn piggybacked_fetch_resets_the_staleness_clock() {
        let f = fabric(2, 2).with_meta_refresh_rounds(2);
        f.gather_counts(0).unwrap(); // round 0: RPC refresh
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 1);
        f.buffer(1).insert(Sample::new(0, vec![7.0, 7.0]));
        // the fetch piggybacks peer 1's post-insert snapshot
        f.fetch_bulk(0, 1, &[(0, 0)]).unwrap();
        // round 1 serves the piggybacked (fresher-than-cadence) view with
        // no further metadata RPC...
        let counts = f.gather_counts(0).unwrap();
        assert_eq!(counts[1][0], (0, 3), "piggyback must refresh the cache");
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 1,
                   "piggybacks must not be counted as metadata frames");
        // ...and the piggyback landed during round 0, so round 2 (staleness
        // 2 ≥ k) re-RPCs on cadence.
        f.gather_counts(0).unwrap();
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn k1_always_refreshes_like_the_uncached_fabric() {
        let f = fabric(3, 2).with_meta_refresh_rounds(1);
        // even a piggybacked fetch between rounds must not suppress the
        // per-round RPCs at k = 1 (bit-identical plans guarantee)
        f.gather_counts(0).unwrap();
        f.fetch_bulk(0, 1, &[(0, 0)]).unwrap();
        f.gather_counts(0).unwrap();
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn zero_cadence_clamps_to_one() {
        let f = fabric(2, 1).with_meta_refresh_rounds(0);
        assert_eq!(f.meta_refresh_rounds(), 1);
    }

    #[test]
    fn elastic_fabric_degrades_and_commits_the_loss_at_the_boundary() {
        // Peer 1 dead from op 0. Elastic mode: rounds keep succeeding
        // (empty/stale views of the dead peer), every live failure is
        // counted, and the epoch-boundary commit turns the pending loss
        // into agreed membership — after which the peer is skipped
        // silently (no probe traffic, no further degraded counts).
        let t = FaultyTransport::new(
            Box::new(InprocTransport::new(buffers(3, 4))),
            FaultPlan::parse("kill:1@0").unwrap(), 5);
        let f = Fabric::with_transport(Box::new(t), CostModel::default(),
                                       false)
            .with_elastic(true);
        let all = f.gather_counts(0).unwrap();
        assert_eq!(all.len(), 3);
        assert!(all[1].is_empty(), "dead peer must look empty to the planner");
        assert!(!all[2].is_empty(), "live peer unaffected");
        let (rows, wire) = f.fetch_bulk(0, 1, &[(0, 0)]).unwrap();
        assert!(rows.is_empty() && wire.is_zero(),
                "fetch from the dying peer degrades to the local fallback");
        assert_eq!(f.counters.degraded(), 2);
        assert!(f.membership().is_alive(1), "loss is pending, not committed");
        f.gather_counts(0).unwrap(); // third strike crosses the budget
        assert_eq!(f.membership().pending_losses(), vec![1]);
        assert_eq!(f.advance_membership_epoch(), Some(vec![1]));
        assert_eq!(f.membership().epoch(), 1);
        assert_eq!(f.membership().survivors(), vec![0, 2]);
        let before = f.counters.degraded();
        let all = f.gather_counts(0).unwrap();
        assert!(all[1].is_empty());
        let (rows, _) = f.fetch_bulk(0, 1, &[(0, 0)]).unwrap();
        assert!(rows.is_empty());
        assert_eq!(f.counters.degraded(), before,
                   "a committed loss is skipped, not re-discovered");
        assert_eq!(f.advance_membership_epoch(), None);
    }

    #[test]
    fn non_elastic_fabric_still_poisons_on_peer_failure() {
        // Default (elastic = false): the PR-9 machinery must be inert —
        // a peer failure surfaces as an error exactly as before.
        let t = FaultyTransport::new(
            Box::new(InprocTransport::new(buffers(2, 2))),
            FaultPlan::parse("kill:1@0").unwrap(), 5);
        let f = Fabric::with_transport(Box::new(t), CostModel::default(),
                                       false);
        assert!(!f.is_elastic());
        assert!(f.gather_counts(0).is_err());
        assert!(f.fetch_bulk(0, 1, &[(0, 0)]).is_err());
        assert_eq!(f.counters.degraded(), 0);
    }

    #[test]
    fn tcp_backend_serves_the_same_rpcs() {
        let f = Fabric::over_tcp(buffers(3, 5), CostModel::default(), false)
            .unwrap();
        assert_eq!(f.transport_kind(), TransportKind::Tcp);
        let all = f.gather_counts(0).unwrap();
        assert_eq!(all.len(), 3);
        assert_eq!(f.counters.meta_rpcs.load(Ordering::Relaxed), 2);

        let (rows, wire) = f.fetch_bulk(0, 1, &[(1, 0), (2, 3)]).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|s| s.features[0] == 1.0));
        assert!(wire > Duration::ZERO);
        assert_eq!(f.counters.rpcs.load(Ordering::Relaxed), 1);
        // actual frame bytes exceed the semantic payload (framing overhead)
        let semantic: u64 = rows.iter().map(Sample::wire_bytes).sum::<usize>() as u64;
        assert!(f.counters.bytes.load(Ordering::Relaxed) > semantic);
        f.shutdown().unwrap();
    }
}
