//! Simulated RDMA/RPC fabric — the Mochi/Thallium stand-in (DESIGN.md §1).
//!
//! The paper pins each local rehearsal buffer and exposes it for RDMA so any
//! process can read any other process's representatives with low-overhead
//! one-sided operations. The in-process analogue: every worker's
//! `Arc<LocalBuffer>` is registered with the [`Fabric`]; a *bulk fetch* is a
//! direct memory read of the peer buffer (one-sided, no peer CPU involved —
//! the RDMA semantics) plus a calibrated wire-cost charge from the
//! [`CostModel`] (ConnectX-6-like latency + bandwidth). Costs are always
//! *accounted* (virtual time for the perfmodel and Fig. 6/7 harnesses) and
//! optionally *emulated* by sleeping, for wall-clock overlap experiments.

pub mod cost;
pub mod fabric;

pub use cost::CostModel;
pub use fabric::{Fabric, FabricCounters};
