//! The RDMA/RPC fabric — the Mochi/Thallium slot of the paper's stack
//! (DESIGN.md §1) — now with pluggable backends.
//!
//! The paper pins each local rehearsal buffer and exposes it for RDMA so any
//! process can read any other process's representatives with low-overhead
//! one-sided operations. Here the [`Fabric`] owns that *policy* layer —
//! consolidation accounting, the calibrated [`CostModel`] (ConnectX-6-like
//! latency + bandwidth), traffic counters, optional wall-clock delay
//! emulation — and delegates the *mechanism* to a [`Transport`]:
//!
//! - **`inproc`** ([`InprocTransport`], default): every worker's
//!   `Arc<LocalBuffer>` is read directly; a bulk fetch is a one-sided
//!   memory read (no peer CPU involved — the RDMA semantics) and fetched
//!   rows share their `Arc<[f32]>` feature slabs with the buffer.
//! - **`tcp`** ([`TcpTransport`]): the same RPCs over real `std::net`
//!   sockets — one listener thread per worker serving its buffer with the
//!   length-prefixed binary protocol in [`wire`], one pooled connection per
//!   (requester, target) pair. Rows arrive as decoded copies.
//!
//! # Which guarantees are universal, which per-backend
//!
//! Universal (any backend): fetched rows are value-identical to the stored
//! samples (features travel as raw LE `f32` bits); `rpcs`/`meta_rpcs`
//! counts depend only on the sampling plans and the metadata cadence;
//! virtual wire time is priced from the semantic payload (`4·d + 8` per
//! row, 12 bytes per snapshot entry — including the snapshot piggybacked
//! on every remote fetch), so Fig. 6/7 projections are backend-independent;
//! local fetches are free on the wire; transport teardown joins every
//! thread it spawned.
//!
//! # Bounded-staleness metadata plane
//!
//! `gather_counts` serves the planner from a per-(requester, target)
//! counts cache refreshed every `meta_refresh_rounds` rounds by a real
//! metadata RPC and opportunistically by the snapshot piggybacked on every
//! `fetch_bulk` response. The planner's view of a peer is thus at most
//! `k` rounds stale, amortized metadata RPCs drop from `N−1` per
//! worker-iteration to `≤ (N−1)/k`, and `k = 1` (the default) reproduces
//! the uncached fabric's plans bit-identically (see [`fabric`]).
//!
//! `inproc` only: `Arc::ptr_eq` sharing between fetched rows and buffer
//! residents (zero-copy), and `FabricCounters.bytes` equal to the semantic
//! payload. On `tcp`, `bytes` reports the frames actually written
//! (payload + length prefixes + request), which is strictly larger.

pub mod cost;
pub mod fabric;
pub mod transport;
pub mod wire;

pub use cost::CostModel;
pub use fabric::{Fabric, FabricCounters};
pub use transport::{FaultPlan, FaultyTransport, InprocTransport,
                    TcpTransport, Transport};
