//! Wire-cost model for the simulated fabric.
//!
//! `cost(bytes) = latency + bytes / bandwidth` — the standard alpha-beta
//! (Hockney) model. Defaults approximate the paper's testbed NICs (NVIDIA
//! ConnectX-6, ~2 µs one-way RPC latency through Mercury, ~12 GiB/s
//! per-process share of a 200 Gb/s HDR link). The same model prices the
//! all-reduce ring in [`crate::cluster`] and the perfmodel projections.

use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// One-way small-RPC latency (alpha), microseconds.
    pub latency_us: f64,
    /// Bulk bandwidth (1/beta), GiB/s.
    pub bandwidth_gibps: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { latency_us: 2.0, bandwidth_gibps: 12.0 }
    }
}

impl CostModel {
    pub fn new(latency_us: f64, bandwidth_gibps: f64) -> CostModel {
        CostModel { latency_us, bandwidth_gibps }
    }

    /// Wire time for one message of `bytes` payload.
    pub fn cost(&self, bytes: usize) -> Duration {
        let secs = self.latency_us * 1e-6
            + bytes as f64 / (self.bandwidth_gibps * 1024.0 * 1024.0 * 1024.0);
        Duration::from_secs_f64(secs)
    }

    /// Cost of `k` *separate* messages with the same total payload — what
    /// the consolidation optimisation (paper §IV-C) saves versus one bulk
    /// RPC: (k-1) extra latency terms.
    pub fn cost_unconsolidated(&self, bytes: usize, k: usize) -> Duration {
        if k == 0 {
            return Duration::ZERO;
        }
        let secs = self.latency_us * 1e-6 * k as f64
            + bytes as f64 / (self.bandwidth_gibps * 1024.0 * 1024.0 * 1024.0);
        Duration::from_secs_f64(secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_floor() {
        let m = CostModel::new(2.0, 12.0);
        let c = m.cost(0);
        assert!((c.as_secs_f64() - 2e-6).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_term_scales() {
        let m = CostModel::new(0.0, 1.0); // 1 GiB/s
        let c = m.cost(1024 * 1024 * 1024);
        assert!((c.as_secs_f64() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn consolidation_saves_latency() {
        let m = CostModel::default();
        let bulk = m.cost(7 * 12 * 1024);
        let split = m.cost_unconsolidated(7 * 12 * 1024, 7);
        assert!(split > bulk);
        let saved = split.as_secs_f64() - bulk.as_secs_f64();
        assert!((saved - 6.0 * 2e-6).abs() < 1e-12);
    }
}
