//! Optimizer-side logic that lives in the coordinator: learning-rate
//! schedules (paper §VI-A). The SGD-momentum update itself is the fused L1
//! Pallas kernel inside the `update` artifact; the coordinator only decides
//! the scalar LR each iteration.

pub mod schedule;

pub use schedule::LrSchedule;
