//! Learning-rate schedule (paper §VI-A):
//!
//! - **Linear scaling rule** (Goyal et al.): peak LR = base·N for N
//!   data-parallel workers…
//! - …capped at `max_lr_scale`·base — the paper's mitigation for >8K global
//!   batches ("maximum rate independent of the mini-batch size equal to
//!   64", citing Bottou & Nocedal);
//! - **per-task warmup**: LR ramps linearly from base to peak over the first
//!   `warmup_epochs` of each task;
//! - **step decay**: multiplicative factors at fixed epochs within the task
//!   (ResNet: ×0.5 @21, ×0.05 @26, ×0.01 @28 — factors are absolute
//!   multipliers of the peak, as in the paper's description).

#[derive(Clone, Debug)]
pub struct LrSchedule {
    base_lr: f64,
    peak_lr: f64,
    warmup_epochs: usize,
    /// (epoch-within-task, absolute multiplier of peak).
    decay_points: Vec<(usize, f64)>,
}

impl LrSchedule {
    pub fn new(base_lr: f64, workers: usize, max_lr_scale: f64,
               warmup_epochs: usize, decay_points: Vec<(usize, f64)>) -> LrSchedule {
        let scale = (workers as f64).min(max_lr_scale);
        let mut pts = decay_points;
        pts.sort_by_key(|&(e, _)| e);
        LrSchedule {
            base_lr,
            peak_lr: base_lr * scale,
            warmup_epochs,
            decay_points: pts,
        }
    }

    pub fn peak_lr(&self) -> f64 {
        self.peak_lr
    }

    /// LR for `epoch` within the current task (every task restarts the
    /// warmup + decay cycle, as the paper's per-task warmup prescribes).
    pub fn lr_at(&self, epoch_in_task: usize) -> f64 {
        if epoch_in_task < self.warmup_epochs {
            // linear ramp base → peak, reaching peak at warmup_epochs
            let frac = (epoch_in_task + 1) as f64 / self.warmup_epochs as f64;
            return self.base_lr + (self.peak_lr - self.base_lr) * frac;
        }
        let mut mult = 1.0;
        for &(e, m) in &self.decay_points {
            if epoch_in_task >= e {
                mult = m;
            }
        }
        self.peak_lr * mult
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_scaling_with_cap() {
        let s = LrSchedule::new(0.0125, 16, 64.0, 0, vec![]);
        assert!((s.peak_lr() - 0.2).abs() < 1e-12);
        let s = LrSchedule::new(0.0125, 128, 64.0, 0, vec![]);
        assert!((s.peak_lr() - 0.8).abs() < 1e-12, "capped at 64x");
    }

    #[test]
    fn warmup_ramps_to_peak() {
        let s = LrSchedule::new(0.1, 4, 64.0, 5, vec![]);
        let lr0 = s.lr_at(0);
        let lr4 = s.lr_at(4);
        assert!(lr0 > 0.1 && lr0 < s.peak_lr());
        assert!((lr4 - s.peak_lr()).abs() < 1e-12);
        // monotone during warmup
        for e in 1..5 {
            assert!(s.lr_at(e) > s.lr_at(e - 1));
        }
    }

    #[test]
    fn paper_decay_schedule() {
        let s = LrSchedule::new(0.0125, 16, 64.0, 5,
                                vec![(21, 0.5), (26, 0.05), (28, 0.01)]);
        let peak = s.peak_lr();
        assert!((s.lr_at(10) - peak).abs() < 1e-12);
        assert!((s.lr_at(21) - peak * 0.5).abs() < 1e-12);
        assert!((s.lr_at(25) - peak * 0.5).abs() < 1e-12);
        assert!((s.lr_at(26) - peak * 0.05).abs() < 1e-12);
        assert!((s.lr_at(29) - peak * 0.01).abs() < 1e-12);
    }

    #[test]
    fn unsorted_decay_points_are_sorted() {
        let s = LrSchedule::new(1.0, 1, 64.0, 0, vec![(8, 0.05), (6, 0.5)]);
        assert!((s.lr_at(7) - 0.5).abs() < 1e-12);
        assert!((s.lr_at(8) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn no_warmup_starts_at_peak() {
        let s = LrSchedule::new(0.5, 2, 64.0, 0, vec![]);
        assert!((s.lr_at(0) - 1.0).abs() < 1e-12);
    }
}
