//! Property tests on task-scenario invariants (ISSUE 8): whatever the
//! geometry, no scenario may lose classes or training samples, and the
//! blurry leak stays bounded by the configured mix.

use dcl::config::{DataConfig, ScenarioKind};
use dcl::data::{Dataset, Scenario};
use dcl::testkit::prop::{forall, usize_in};
use dcl::util::rng::Rng;

fn any_cfg(rng: &mut Rng, kind: ScenarioKind) -> DataConfig {
    let num_tasks = usize_in(rng, 1, 6);
    DataConfig {
        num_classes: usize_in(rng, num_tasks, 16),
        num_tasks,
        train_per_class: usize_in(rng, 2, 12),
        val_per_class: 1,
        noise_std: 0.4,
        augment: false,
        seed: rng.next_u64(),
        scenario: kind,
        blurry_mix: rng.f64() * 0.9,
        imbalance_ratio: 1.0 + rng.f64() * 5.0,
        drift_strength: rng.f64() * 2.0,
        ..DataConfig::default()
    }
}

fn split_kinds() -> [ScenarioKind; 4] {
    [ScenarioKind::ClassIncremental, ScenarioKind::Imbalanced,
     ScenarioKind::Blurry, ScenarioKind::Online]
}

#[test]
fn split_scenarios_never_lose_classes() {
    forall(40, |rng| {
        for kind in split_kinds() {
            let d = any_cfg(rng, kind);
            let sc = Scenario::from_config(&d).map_err(|e| e.to_string())?;
            let mut all: Vec<usize> = (0..sc.num_tasks())
                .flat_map(|t| sc.classes(t).to_vec())
                .collect();
            all.sort_unstable();
            if all != (0..d.num_classes).collect::<Vec<_>>() {
                return Err(format!(
                    "{kind:?} K={} T={} lost or duplicated classes: {all:?}",
                    d.num_classes, d.num_tasks));
            }
        }
        Ok(())
    });
}

#[test]
fn train_pools_partition_every_split_scenario() {
    // Union of the per-task pools must be each training index exactly
    // once — blurry leaks move samples between streams, never drop them.
    forall(25, |rng| {
        for kind in split_kinds() {
            let d = any_cfg(rng, kind);
            let ds = Dataset::generate(&d);
            let sc = Scenario::from_config(&d).map_err(|e| e.to_string())?;
            let mut all: Vec<usize> = (0..sc.num_tasks())
                .flat_map(|t| sc.train_pool(&ds, t))
                .collect();
            all.sort_unstable();
            if all != (0..ds.train_len()).collect::<Vec<_>>() {
                return Err(format!(
                    "{kind:?} K={} T={} pools are not a partition",
                    d.num_classes, d.num_tasks));
            }
        }
        Ok(())
    });
}

#[test]
fn blurry_leak_is_bounded_by_the_mix() {
    // Each task keeps at least (1 - mix) of its own classes' samples.
    forall(25, |rng| {
        let d = any_cfg(rng, ScenarioKind::Blurry);
        let ds = Dataset::generate(&d);
        let sc = Scenario::from_config(&d).map_err(|e| e.to_string())?;
        for t in 0..sc.num_tasks() {
            let home = ds.train_indices_of_classes(sc.classes(t)).len();
            let pool = sc.train_pool(&ds, t);
            let kept = pool.iter()
                .filter(|&&i| sc.classes(t)
                    .contains(&(ds.train[i].label as usize)))
                .count();
            let min_kept = ((1.0 - d.blurry_mix) * home as f64).floor() as usize;
            if kept < min_kept {
                return Err(format!(
                    "task {t} kept {kept}/{home} own-class samples, \
                     mix {} allows no fewer than {min_kept}",
                    d.blurry_mix));
            }
        }
        Ok(())
    });
}

#[test]
fn domain_scenario_sees_all_classes_each_task() {
    forall(25, |rng| {
        let d = any_cfg(rng, ScenarioKind::DomainIncremental);
        let ds = Dataset::generate(&d);
        let sc = Scenario::from_config(&d).map_err(|e| e.to_string())?;
        for t in 0..sc.num_tasks() {
            if sc.classes(t).len() != d.num_classes {
                return Err(format!("task {t} sees a partial label set"));
            }
            if sc.train_pool(&ds, t).len() != ds.train_len() {
                return Err(format!("task {t} pool misses samples"));
            }
            if t > 0 && d.drift_strength > 0.0 && sc.drift(t).is_none() {
                return Err(format!("task {t} lost its drift"));
            }
        }
        Ok(())
    });
}
