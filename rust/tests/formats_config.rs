//! Cross-module integration of the in-repo substrates: config files parsed
//! by the TOML subset drive real `ExperimentConfig`s; the JSON parser
//! round-trips the actual artifact manifest; property tests fuzz both
//! parsers against crashes.

use dcl::config::{ExperimentConfig, Strategy};
use dcl::formats::json::Json;
use dcl::formats::toml::TomlTable;
use dcl::testkit::prop::{forall, usize_in};

#[test]
fn example_config_file_round_trip() {
    let text = r#"
preset = "default"
name = "my-experiment"

[data]
num_classes = 20
num_tasks = 4
train_per_class = 100
val_per_class = 10

[training]
variant = "resnet18_sim"
strategy = "scratch"
epochs_per_task = 5
eval_batch = 50

[buffer]
percent_of_dataset = 10.0
policy = "reservoir"

[cluster]
workers = 8
rpc_latency_us = 1.5
"#;
    let doc = TomlTable::parse(text).unwrap();
    let cfg = ExperimentConfig::from_toml(&doc).unwrap();
    assert_eq!(cfg.name, "my-experiment");
    assert_eq!(cfg.data.num_classes, 20);
    assert_eq!(cfg.training.strategy, Strategy::FromScratch);
    assert_eq!(cfg.cluster.workers, 8);
    assert!((cfg.cluster.rpc_latency_us - 1.5).abs() < 1e-12);
    assert_eq!(cfg.global_buffer_capacity(), 200); // 10% of 20*100
}

#[test]
fn bad_config_values_fail_validation() {
    for (snippet, why) in [
        ("[data]\nnum_classes = 41", "not divisible by tasks"),
        ("[training]\ncandidates = 200", "c > b"),
        ("[buffer]\npercent_of_dataset = 0.0", "zero buffer"),
        ("[cluster]\nworkers = 0", "no workers"),
        ("[training]\nstrategy = \"sgd\"", "unknown strategy"),
    ] {
        let text = format!("preset = \"default\"\n{snippet}");
        let doc = TomlTable::parse(&text).unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err(), "{why}");
    }
}

#[test]
fn manifest_json_parses_if_artifacts_exist() {
    let Some(dir) = dcl::testkit::artifacts_dir() else { return };
    let j = Json::parse_file(&dir.join("manifest.json")).unwrap();
    // round-trip through our writer and parser
    let text = j.to_string();
    let j2 = Json::parse(&text).unwrap();
    assert_eq!(j, j2);
    assert!(j.get("variants").unwrap().as_object().unwrap().len() >= 1);
}

#[test]
fn json_parser_never_panics_on_noise() {
    forall(300, |rng| {
        let len = usize_in(rng, 0, 60);
        let charset: Vec<char> =
            "{}[]\",:truefalsnl0123456789.eE+- \\x".chars().collect();
        let s: String = (0..len)
            .map(|_| charset[rng.below(charset.len())])
            .collect();
        let _ = Json::parse(&s); // Err is fine; panic is not
        Ok(())
    });
}

#[test]
fn toml_parser_never_panics_on_noise() {
    forall(300, |rng| {
        let len = usize_in(rng, 0, 60);
        let charset: Vec<char> =
            "[]=\"#\n abcdefgh0123456789._-,".chars().collect();
        let s: String = (0..len)
            .map(|_| charset[rng.below(charset.len())])
            .collect();
        let _ = TomlTable::parse(&s);
        Ok(())
    });
}

#[test]
fn json_roundtrip_random_documents() {
    // generate random JSON values, serialize, reparse, compare
    fn gen(rng: &mut dcl::util::rng::Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Int(rng.next_u64() as i64 / 1000),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Array((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Object(m)
            }
        }
    }
    forall(200, |rng| {
        let doc = gen(rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text)
            .map_err(|e| format!("reparse failed: {e} on `{text}`"))?;
        if back != doc {
            return Err(format!("round-trip mismatch: {text}"));
        }
        Ok(())
    });
}
