//! Thread-teardown regression (promoted from the old `examples/_leak2.rs`
//! repro): every thread the runtime spawns — worker threads, loader
//! prefetchers, engine background threads — must be joined by the time its
//! owner returns. A leak here used to accumulate one engine thread per
//! task across long experiment sweeps.
//!
//! The check reads `Threads:` from /proc/self/status, so it is a no-op on
//! non-Linux hosts. It is the only test in this binary on purpose: a
//! process-wide thread count cannot be asserted while sibling tests spawn
//! workers concurrently.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dcl::buffer::LocalBuffer;
use dcl::config::{PolicyKind, SamplingScope, Strategy, TransportKind};
use dcl::engine::{EngineParams, RehearsalEngine};
use dcl::net::{CostModel, Fabric};
use dcl::tensor::{Batch, Sample};
use dcl::train::trainer::run_experiment;

fn thread_count() -> Option<usize> {
    let s = std::fs::read_to_string("/proc/self/status").ok()?;
    s.lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// Wait (bounded) for the count to drop back to `baseline`; exiting
/// threads are reaped by join, but give the OS a moment to settle.
fn settles_to(baseline: usize) -> bool {
    let t0 = Instant::now();
    loop {
        match thread_count() {
            None => return true, // not Linux — nothing to assert
            Some(n) if n <= baseline => return true,
            Some(_) if t0.elapsed() > Duration::from_secs(5) => return false,
            Some(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

#[test]
fn no_thread_outlives_its_owner() {
    let Some(baseline) = thread_count() else { return };

    // --- engines: spawn, drive, shutdown ---------------------------------
    {
        let buffers = (0..4)
            .map(|w| Arc::new(LocalBuffer::new(100, PolicyKind::Uniform, w as u64)))
            .collect();
        let fabric = Arc::new(Fabric::new(buffers, CostModel::default(), false));
        let params = EngineParams {
            batch: 8,
            reps: 4,
            candidates: 8,
            scope: SamplingScope::Global,
            async_updates: true,
        };
        let mut engines: Vec<RehearsalEngine> = (0..4)
            .map(|w| RehearsalEngine::new(w, Arc::clone(&fabric), params, w as u64))
            .collect();
        assert!(engines.iter().all(|e| !e.is_shut_down()),
                "async engines must have live background threads");
        for i in 0..6u32 {
            for e in &mut engines {
                let batch = Batch::new(
                    (0..8).map(|j| Sample::new(i % 3, vec![j as f32; 8])).collect());
                e.update(&batch).unwrap();
            }
        }
        // explicit shutdown joins the handles...
        for e in &mut engines {
            e.shutdown().unwrap();
            assert!(e.is_shut_down());
        }
        drop(engines);
    }
    assert!(settles_to(baseline),
            "engine threads leaked: {:?} > baseline {baseline}", thread_count());

    // --- full trainer run: workers + loaders + engines -------------------
    let mut cfg = dcl::testkit::tiny_config().expect("tiny config");
    cfg.training.epochs_per_task = 1;
    cfg.training.strategy = Strategy::Rehearsal;
    cfg.validate().unwrap();
    let report = run_experiment(&cfg).expect("rehearsal run");
    assert!(report.iterations > 0);
    assert!(settles_to(baseline),
            "trainer threads leaked: {:?} > baseline {baseline}", thread_count());

    // --- same run over the TCP transport: listener + connection threads --
    let mut cfg = dcl::testkit::tiny_config().expect("tiny config");
    cfg.training.epochs_per_task = 1;
    cfg.training.strategy = Strategy::Rehearsal;
    cfg.cluster.transport = TransportKind::Tcp;
    cfg.validate().unwrap();
    let report = run_experiment(&cfg).expect("tcp rehearsal run");
    assert!(report.iterations > 0);
    assert!(settles_to(baseline),
            "tcp fabric threads (listener/serve) leaked: {:?} > baseline \
             {baseline}", thread_count());

    // a *failing* TCP run must also reap everything: kill peer 1's
    // transport endpoint from the first op (non-elastic, so the error
    // poisons the run mid-epoch) and check the error path joins every
    // worker, loader, engine and fabric thread it spawned (PR 9)
    let mut cfg = dcl::testkit::tiny_config().expect("tiny config");
    cfg.training.epochs_per_task = 1;
    cfg.training.strategy = Strategy::Rehearsal;
    cfg.cluster.workers = 2;
    cfg.cluster.transport = TransportKind::Tcp;
    cfg.cluster.fault_plan = "kill:1@0".to_string();
    cfg.validate().unwrap();
    run_experiment(&cfg).expect_err("dead peer without elastic mode must fail");
    assert!(settles_to(baseline),
            "poisoned tcp run leaked a thread: {:?} > baseline {baseline}",
            thread_count());

    // a TCP fabric torn down by Drop alone must also reap its threads
    {
        let buffers = (0..3)
            .map(|w| Arc::new(LocalBuffer::new(50, PolicyKind::Uniform, w as u64)))
            .collect();
        let fabric = dcl::net::Fabric::over_tcp(
            buffers, CostModel::default(), false).expect("loopback fabric");
        for w in 0..3 {
            fabric.buffer(w).insert(Sample::new(0, vec![w as f32]));
        }
        fabric.fetch_bulk(0, 1, &[(0, 0)]).unwrap();
        fabric.fetch_bulk(2, 1, &[(0, 0)]).unwrap();
        drop(fabric); // no explicit shutdown
    }
    assert!(settles_to(baseline),
            "dropped TCP fabric leaked a thread: {:?} > baseline {baseline}",
            thread_count());

    // dropping with a round in flight must also tear down cleanly
    {
        let buffers = (0..2)
            .map(|w| Arc::new(LocalBuffer::new(50, PolicyKind::Uniform, w as u64)))
            .collect();
        let fabric = Arc::new(Fabric::new(buffers, CostModel::default(), false));
        let params = EngineParams {
            batch: 8,
            reps: 2,
            candidates: 8,
            scope: SamplingScope::Global,
            async_updates: true,
        };
        let mut e = RehearsalEngine::new(0, fabric, params, 9);
        let batch = Batch::new((0..8).map(|j| Sample::new(0, vec![j as f32])).collect());
        e.update(&batch).unwrap();
        drop(e); // no explicit finish
    }
    assert!(settles_to(baseline),
            "mid-flight drop leaked a thread: {:?} > baseline {baseline}",
            thread_count());
}
