//! Property tests on rehearsal-buffer invariants (DESIGN.md §5), via the
//! in-repo `testkit::prop` harness.

use dcl::buffer::{ClassBuffer, InsertOutcome, LocalBuffer};
use dcl::config::PolicyKind;
use dcl::tensor::Sample;
use dcl::testkit::prop::{forall, usize_in};
use dcl::util::rng::Rng;

fn sample(class: u32, tag: f32) -> Sample {
    Sample::new(class, vec![tag])
}

fn any_policy(rng: &mut Rng) -> PolicyKind {
    let all = PolicyKind::all();
    all[rng.below(all.len())]
}

#[test]
fn class_buffer_never_exceeds_capacity() {
    forall(60, |rng| {
        let cap = usize_in(rng, 0, 40);
        let policy = any_policy(rng);
        let inserts = usize_in(rng, 0, 300);
        let mut cb = ClassBuffer::new(cap, policy, rng.next_u64());
        for i in 0..inserts {
            cb.insert(sample(0, i as f32), rng.f32());
            if cb.len() > cap {
                return Err(format!("len {} > cap {cap} ({policy:?})", cb.len()));
            }
        }
        if cb.seen() != inserts as u64 {
            return Err("seen counter drift".into());
        }
        Ok(())
    });
}

#[test]
fn class_buffer_fills_before_evicting() {
    forall(40, |rng| {
        let cap = usize_in(rng, 1, 30);
        let policy = any_policy(rng);
        let mut cb = ClassBuffer::new(cap, policy, rng.next_u64());
        for i in 0..cap {
            match cb.insert(sample(0, i as f32), rng.f32()) {
                InsertOutcome::Appended => {}
                o => return Err(format!("unexpected {o:?} before full")),
            }
        }
        Ok(())
    });
}

#[test]
fn disjoint_union_invariant() {
    // Σ_i |R_n^i| == |B_n| under arbitrary interleavings of inserts.
    forall(40, |rng| {
        let s_max = usize_in(rng, 1, 200);
        let classes = usize_in(rng, 1, 12) as u32;
        let buf = LocalBuffer::new(s_max, any_policy(rng), rng.next_u64());
        let inserts = usize_in(rng, 0, 400);
        for i in 0..inserts {
            buf.insert(sample(rng.below(classes as usize) as u32, i as f32));
        }
        let total: usize = buf.snapshot_counts().iter().map(|&(_, n)| n).sum();
        if total != buf.len() {
            return Err(format!("Σ counts {total} != len {}", buf.len()));
        }
        if buf.len() > s_max {
            return Err(format!("len {} > S_max {s_max}", buf.len()));
        }
        Ok(())
    });
}

#[test]
fn per_class_capacity_is_even_split() {
    forall(40, |rng| {
        let s_max = usize_in(rng, 1, 300);
        let classes = usize_in(rng, 1, 20) as u32;
        let buf = LocalBuffer::new(s_max, PolicyKind::Uniform, rng.next_u64());
        // saturate every class
        for round in 0..(s_max + 50) {
            for c in 0..classes {
                buf.insert(sample(c, round as f32));
            }
        }
        let cap = (s_max / classes as usize).max(1);
        for (c, n) in buf.snapshot_counts() {
            if n > cap {
                return Err(format!(
                    "class {c} holds {n} > even-split cap {cap} \
                     (S_max={s_max}, K={classes})"));
            }
        }
        Ok(())
    });
}

#[test]
fn eviction_competes_within_class_only() {
    // Filling class B never reduces class A's count below its cap share.
    forall(30, |rng| {
        let buf = LocalBuffer::new(100, PolicyKind::Uniform, rng.next_u64());
        for i in 0..50 {
            buf.insert(sample(0, i as f32));
        }
        let a_before = buf.snapshot_counts()[0].1;
        for i in 0..500 {
            buf.insert(sample(1, i as f32));
        }
        let counts = buf.snapshot_counts();
        let a_after = counts.iter().find(|&&(c, _)| c == 0).unwrap().1;
        // class 0 may shrink once (rebalance to 50) but never below cap
        if a_after < 100 / 2 && a_after < a_before {
            return Err(format!("class 0 shrank {a_before} -> {a_after}"));
        }
        Ok(())
    });
}

#[test]
fn fetch_rows_returns_requested_classes() {
    forall(40, |rng| {
        let classes = usize_in(rng, 1, 8) as u32;
        let buf = LocalBuffer::new(400, PolicyKind::Uniform, rng.next_u64());
        for c in 0..classes {
            for i in 0..usize_in(rng, 1, 20) {
                buf.insert(sample(c, i as f32));
            }
        }
        let counts = buf.snapshot_counts();
        let picks: Vec<(u32, usize)> = (0..usize_in(rng, 1, 10))
            .map(|_| {
                let (c, n) = counts[rng.below(counts.len())];
                (c, rng.below(n))
            })
            .collect();
        let rows = buf.fetch_rows(&picks).map_err(|e| e.to_string())?;
        for (row, &(c, _)) in rows.iter().zip(&picks) {
            if row.label != c {
                return Err(format!("asked class {c}, got {}", row.label));
            }
        }
        Ok(())
    });
}

#[test]
fn loss_aware_max_resident_score_never_decreases() {
    // LossAware evicts the argmin score, so once a hard (high-loss) sample
    // is resident the class maximum can only go up.
    forall(40, |rng| {
        let cap = usize_in(rng, 2, 20);
        let mut cb = ClassBuffer::new(cap, PolicyKind::LossAware,
                                      rng.next_u64());
        let mut prev_max = f32::NEG_INFINITY;
        for i in 0..usize_in(rng, cap, 200) {
            cb.insert(sample(0, i as f32), rng.f32());
            let max = (0..cb.len())
                .map(|j| cb.score(j))
                .fold(f32::NEG_INFINITY, f32::max);
            if cb.len() == cap && max < prev_max {
                return Err(format!("max score fell {prev_max} -> {max}"));
            }
            prev_max = max;
        }
        Ok(())
    });
}

#[test]
fn grasp_selectable_window_is_monotone_and_bounded() {
    forall(40, |rng| {
        let cap = usize_in(rng, 1, 24);
        let mut cb = ClassBuffer::new(cap, PolicyKind::Grasp, rng.next_u64());
        for i in 0..usize_in(rng, 1, 3 * cap) {
            cb.insert(sample(0, i as f32), rng.f32());
        }
        let mut prev = 0usize;
        for fetches in 0..40 {
            let sel = cb.selectable_len();
            if sel == 0 || sel > cb.len() {
                return Err(format!("window {sel} outside (0, len={}] \
                                    after {fetches} fetches", cb.len()));
            }
            if sel < prev {
                return Err(format!("window shrank {prev} -> {sel}"));
            }
            prev = sel;
            cb.fetch(rng.below(1 << 20));
        }
        Ok(())
    });
}

#[test]
fn insert_outcome_tallies_partition_the_offers() {
    // Every candidate offered lands in exactly one of append / evict /
    // reject, whatever the policy.
    forall(30, |rng| {
        use std::sync::atomic::Ordering::Relaxed;
        let buf = LocalBuffer::new(usize_in(rng, 1, 80), any_policy(rng),
                                   rng.next_u64());
        for i in 0..usize_in(rng, 0, 300) {
            buf.insert(sample(rng.below(4) as u32, i as f32));
        }
        let c = &buf.counters;
        let offered = c.candidates_offered.load(Relaxed);
        let split = c.appends.load(Relaxed) + c.evictions.load(Relaxed)
            + c.rejections.load(Relaxed);
        if offered != split {
            return Err(format!("offered {offered} != tally sum {split}"));
        }
        Ok(())
    });
}

#[test]
fn algorithm1_offer_rate_is_c_over_b() {
    forall(10, |rng| {
        let b = usize_in(rng, 8, 64);
        let c = usize_in(rng, 0, b);
        let buf = LocalBuffer::new(100_000, PolicyKind::Uniform, 1);
        let batch: Vec<Sample> =
            (0..b).map(|i| sample((i % 4) as u32, i as f32)).collect();
        let mut urng = Rng::new(rng.next_u64());
        let rounds = 800;
        let mut offered = 0usize;
        for _ in 0..rounds {
            offered += buf.update_with_batch(&batch, c, b, &mut urng);
        }
        let mean = offered as f64 / rounds as f64;
        // binomial(b, c/b): mean c, sd sqrt(c(1-c/b)) < sqrt(b);
        // 800 rounds → se < sqrt(b)/28; allow 5 se + slack
        let tol = (b as f64).sqrt() / 28.0 * 5.0 + 0.2;
        if (mean - c as f64).abs() > tol {
            return Err(format!("offer rate {mean} vs c={c} (b={b})"));
        }
        Ok(())
    });
}
