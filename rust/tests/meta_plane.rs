//! Bounded-staleness metadata plane (ISSUE 3): cadence-cached peer counts
//! with piggybacked refresh must amortize metadata RPCs without changing
//! what the planner *is* — a location-uniform sampler.
//!
//! - With `meta_refresh_rounds = k > 1`, metadata RPCs per worker-round
//!   are `≤ (N−1)/k` amortized, identically over `inproc` and `tcp`.
//! - With `k = 1`, a fixed-seed round stream reproduces the uncached
//!   fabric's plans bit-identically.
//! - Plans built from k-stale cached counts stay location-uniform
//!   (chi-square over the flattened resident space) while the buffers
//!   keep evolving underneath the cache.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dcl::buffer::LocalBuffer;
use dcl::config::{PolicyKind, SamplingScope, TransportKind};
use dcl::net::{CostModel, Fabric};
use dcl::sampling::GlobalSampler;
use dcl::tensor::Sample;
use dcl::testkit::filled_buffers;
use dcl::util::rng::Rng;
use dcl::util::stats::chi_square_uniform;

/// Drive `rounds` gather→plan→execute rounds for worker 0 over `kind` with
/// cadence `k`; returns (meta_rpcs, per-round plans as (target, picks)).
fn drive(kind: TransportKind, k: usize, rounds: usize, seed: u64)
         -> (u64, Vec<Vec<(usize, Vec<(u32, usize)>)>>) {
    let bufs = filled_buffers(4, 6, 2);
    let fabric = Fabric::for_kind(kind, bufs, CostModel::default(), false)
        .expect("fabric")
        .with_meta_refresh_rounds(k);
    let sampler = GlobalSampler::new(0, SamplingScope::Global);
    let mut rng = Rng::new(seed);
    let mut plans = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let counts = fabric.gather_counts(0).unwrap();
        let plan = sampler.plan(&counts, 7, &mut rng);
        sampler.execute(&fabric, &plan).unwrap();
        plans.push(plan.requests.clone());
    }
    let meta = fabric.counters.meta_rpcs.load(Ordering::Relaxed);
    fabric.shutdown().unwrap();
    (meta, plans)
}

#[test]
fn meta_rpcs_amortize_to_peers_over_k() {
    // N = 4, k = 5, 20 rounds: the cadence alone caps refreshes at
    // (N−1) · ceil(rounds/k) = 12, and piggybacked fetches can only lower
    // that — never raise it.
    let (meta, plans) = drive(TransportKind::Inproc, 5, 20, 42);
    let bound: u64 = 3 * 20 / 5;
    assert!(meta <= bound, "meta rpcs {meta} > amortized bound {bound}");
    assert!(meta > 0, "first round must RPC every peer");
    assert_eq!(plans.len(), 20);

    // k = 1 is the uncached rate: exactly N−1 per round.
    let (meta1, _) = drive(TransportKind::Inproc, 1, 20, 42);
    assert_eq!(meta1, 3 * 20u64);
}

#[test]
fn amortization_is_backend_identical() {
    // Same seed, same cadence: the meta RPC count and the plans themselves
    // must not depend on the transport (cache hits and piggybacks are
    // plan-driven, and plans are seed-driven).
    for k in [1usize, 3] {
        let (meta_i, plans_i) = drive(TransportKind::Inproc, k, 15, 7);
        let (meta_t, plans_t) = drive(TransportKind::Tcp, k, 15, 7);
        assert_eq!(meta_i, meta_t, "k={k}: meta rpcs diverged across backends");
        assert_eq!(plans_i, plans_t, "k={k}: plans diverged across backends");
    }
}

#[test]
fn k1_reproduces_uncached_ground_truth_plans() {
    // At k = 1 every gather refreshes by RPC, so plans must be
    // bit-identical to planning straight off the live buffer snapshots
    // with the same RNG stream (today's uncached behavior).
    let bufs = filled_buffers(3, 5, 2);
    let fabric = Fabric::new(bufs.clone(), CostModel::default(), false)
        .with_meta_refresh_rounds(1);
    let sampler = GlobalSampler::new(0, SamplingScope::Global);
    let mut rng_fab = Rng::new(99);
    let mut rng_gt = Rng::new(99);
    for round in 0..10 {
        let via_fabric = {
            let counts = fabric.gather_counts(0).unwrap();
            sampler.plan(&counts, 6, &mut rng_fab)
        };
        let ground_truth = {
            let counts: Vec<Vec<(u32, usize)>> =
                bufs.iter().map(|b| b.snapshot_counts()).collect();
            sampler.plan(&counts, 6, &mut rng_gt)
        };
        assert_eq!(via_fabric, ground_truth, "round {round} diverged at k=1");
        sampler.execute(&fabric, &via_fabric).unwrap();
        // mutate a peer so a (wrongly) cached fabric would diverge
        fabric.buffer(1).insert(Sample::new(0, vec![round as f32, 0.0]));
    }
}

#[test]
fn plans_from_k_stale_counts_stay_location_uniform() {
    // 2 workers × 1 class × 8 residents each, buffers churning under a
    // k = 4 cache: across many rounds every flattened resident position
    // must be picked ~equally often (the paper's fairness requirement
    // holds w.r.t. the snapshot the planner saw).
    let per = 8usize;
    let buffers: Vec<Arc<LocalBuffer>> = (0..2)
        .map(|w| {
            let b = LocalBuffer::new(per, PolicyKind::Uniform, w as u64);
            for i in 0..per {
                b.insert(Sample::new(w as u32, vec![i as f32]));
            }
            Arc::new(b)
        })
        .collect();
    let fabric = Fabric::new(buffers, CostModel::default(), false)
        .with_meta_refresh_rounds(4);
    let sampler = GlobalSampler::new(0, SamplingScope::Global);
    let mut rng = Rng::new(4242);
    let mut churn = Rng::new(777);
    let mut hits = vec![0u64; 2 * per];
    let rounds: u64 = 6000;
    for _ in 0..rounds {
        let counts = fabric.gather_counts(0).unwrap();
        let plan = sampler.plan(&counts, 4, &mut rng);
        for (t, picks) in &plan.requests {
            for &(_, idx) in picks {
                hits[*t * per + idx] += 1;
            }
        }
        // full-buffer churn: counts stay at 8 (random replacement), so the
        // cached view is value-stable but genuinely stale in content
        let w = churn.below(2);
        fabric.buffer(w).insert(
            Sample::new(w as u32, vec![churn.f32(); 1]));
        sampler.execute(&fabric, &plan).unwrap();
    }
    let total: u64 = hits.iter().sum();
    assert_eq!(total, 4 * rounds);
    // 15 dof; 60 is far beyond the 0.9999 quantile
    let chi2 = chi_square_uniform(&hits);
    assert!(chi2 < 60.0, "chi2 {chi2}, hits {hits:?}");
}
