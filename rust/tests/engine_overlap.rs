//! Engine semantics under concurrency: the Fig.-4 pipeline contract
//! (reps lag one iteration; wait ≈ 0 when compute dominates; blocking mode
//! serialises), plus failure injection (dropped engines, saturated
//! buffers, many-worker interleavings).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use dcl::buffer::LocalBuffer;
use dcl::config::{PolicyKind, SamplingScope};
use dcl::engine::{EngineParams, RehearsalEngine};
use dcl::net::{CostModel, Fabric};
use dcl::tensor::{Batch, Sample};
use dcl::testkit::prop::{forall, usize_in};
use dcl::util::rng::Rng;

fn make_fabric(n: usize, s_max: usize) -> Arc<Fabric> {
    let buffers = (0..n)
        .map(|w| Arc::new(LocalBuffer::new(s_max, PolicyKind::Uniform, w as u64)))
        .collect();
    Arc::new(Fabric::new(buffers, CostModel::default(), false))
}

fn batch(class: u32, n: usize) -> Batch {
    Batch::new((0..n).map(|i| Sample::new(class, vec![i as f32; 8])).collect())
}

fn params(b: usize, r: usize, c: usize, async_updates: bool) -> EngineParams {
    EngineParams { batch: b, reps: r, candidates: c,
                   scope: SamplingScope::Global, async_updates }
}

#[test]
fn reps_lag_exactly_one_iteration() {
    // With async updates, the reps returned at iteration i can only contain
    // classes from batches 0..i (not the current batch) — Fig. 4 semantics.
    let fabric = make_fabric(1, 1000);
    let mut e = RehearsalEngine::new(0, fabric, params(8, 4, 8, true), 1);
    for i in 0..10u32 {
        let reps = e.update(&batch(i, 8)).unwrap();
        for s in &reps {
            assert!(s.label < i, "iteration {i} returned label {}", s.label);
        }
    }
    e.finish().unwrap();
}

#[test]
fn overlap_hides_buffer_work_behind_slow_training() {
    // If the caller simulates a 5 ms train step between updates, the
    // background round (≪1 ms here) must produce ~zero foreground wait.
    let fabric = make_fabric(2, 500);
    let mut e = RehearsalEngine::new(0, Arc::clone(&fabric), params(8, 4, 8, true), 2);
    let mut e2 = RehearsalEngine::new(1, fabric, params(8, 4, 8, true), 3);
    for i in 0..20 {
        let _ = e.update(&batch(i % 4, 8)).unwrap();
        let _ = e2.update(&batch(4 + i % 4, 8)).unwrap();
        std::thread::sleep(Duration::from_millis(5)); // the "train step"
    }
    e.finish().unwrap();
    e2.finish().unwrap();
    let wait_ms = e.timings.wait_ns.load(Ordering::Relaxed) as f64 / 1e6;
    let iters = e.timings.iterations.load(Ordering::Relaxed) as f64;
    assert!(wait_ms / iters < 1.0,
            "mean augment-wait {:.3} ms — overlap broken", wait_ms / iters);
}

#[test]
fn blocking_mode_reports_wait() {
    let fabric = make_fabric(2, 500);
    let mut e = RehearsalEngine::new(0, fabric, params(8, 4, 8, false), 4);
    for i in 0..10 {
        let _ = e.update(&batch(i % 4, 8)).unwrap();
    }
    // blocking mode accounts the whole round as wait
    assert!(e.timings.wait_ns.load(Ordering::Relaxed) > 0);
}

#[test]
fn many_workers_interleaved_never_deadlock_or_overflow() {
    forall(8, |rng| {
        let n = usize_in(rng, 1, 6);
        let s_max = usize_in(rng, 4, 60);
        let b = usize_in(rng, 4, 16);
        let r = usize_in(rng, 1, b.min(8));
        let c = usize_in(rng, 0, b);
        let fabric = make_fabric(n, s_max);
        let mut handles = Vec::new();
        for w in 0..n {
            let f = Arc::clone(&fabric);
            let p = params(b, r, c, true);
            let seed = rng.next_u64();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let mut e = RehearsalEngine::new(w, f, p, seed);
                for i in 0..30 {
                    let cls = (w * 7 + i) as u32 % 10;
                    let reps = e.update(&batch(cls, b)).unwrap();
                    assert!(reps.len() <= r);
                    if i % 5 == 0 {
                        let _ = rng.next_u64();
                    }
                }
                e.finish().unwrap();
            }));
        }
        for h in handles {
            h.join().map_err(|_| "worker panicked".to_string())?;
        }
        // buffers never exceed their capacity, union invariant holds
        for w in 0..n {
            let buf = fabric.buffer(w);
            if buf.len() > s_max {
                return Err(format!("worker {w}: {} > S_max {s_max}", buf.len()));
            }
            let sum: usize = buf.snapshot_counts().iter().map(|&(_, k)| k).sum();
            if sum != buf.len() {
                return Err("disjoint-union violated".into());
            }
        }
        Ok(())
    });
}

#[test]
fn engine_drop_mid_flight_is_clean() {
    // Dropping with a round in flight must not hang or poison the fabric.
    let fabric = make_fabric(2, 100);
    {
        let mut e = RehearsalEngine::new(0, Arc::clone(&fabric), params(8, 4, 8, true), 9);
        let _ = e.update(&batch(0, 8)).unwrap();
        // drop without finish()
    }
    // fabric still serviceable
    let mut e2 = RehearsalEngine::new(1, fabric, params(8, 4, 8, true), 10);
    let _ = e2.update(&batch(1, 8)).unwrap();
    let reps = e2.update(&batch(2, 8)).unwrap();
    assert!(reps.len() <= 4);
    e2.finish().unwrap();
}

#[test]
fn candidates_zero_means_buffer_stays_empty() {
    let fabric = make_fabric(1, 100);
    let mut e = RehearsalEngine::new(0, Arc::clone(&fabric), params(8, 4, 0, true), 11);
    for i in 0..10 {
        let reps = e.update(&batch(i, 8)).unwrap();
        assert!(reps.is_empty(), "no candidates → no reps ever");
    }
    e.finish().unwrap();
    assert_eq!(fabric.buffer(0).len(), 0);
}
