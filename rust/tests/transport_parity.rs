//! Loopback parity between the fabric backends (ISSUE 2): the TCP
//! transport is a *mechanism* swap, not a semantics change.
//!
//! - A fixed-seed sampling round executed over TCP must return
//!   byte-identical samples and identical `FabricCounters.rpcs` /
//!   `meta_rpcs` as the in-process backend. Wire `bytes` legitimately
//!   differ (framing overhead) and are asserted separately against the
//!   encoded frame sizes from `net::wire`.
//! - Under the engine-parity deterministic candidate stream (c = b), a
//!   2-worker concurrent engine run over TCP must leave the same per-class
//!   buffer occupancy as over the in-process fabric.
//! - A `workers = 2` rehearsal training run completes end-to-end over
//!   `transport = "tcp"` on loopback.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dcl::buffer::LocalBuffer;
use dcl::config::{PolicyKind, SamplingScope, Strategy, TransportKind};
use dcl::engine::{EngineParams, RehearsalEngine};
use dcl::net::{wire, CostModel, Fabric};
use dcl::sampling::GlobalSampler;
use dcl::tensor::{Batch, Sample};
use dcl::train::trainer::run_experiment;
use dcl::util::rng::Rng;

use dcl::testkit::filled_buffers;

#[test]
fn fixed_seed_sampling_round_is_backend_identical() {
    let bufs = filled_buffers(3, 6, 8);
    let inproc = Fabric::new(bufs.clone(), CostModel::default(), false);
    let tcp = Fabric::over_tcp(bufs.clone(), CostModel::default(), false)
        .expect("loopback fabric");

    let sampler = GlobalSampler::new(0, SamplingScope::Global);
    let mut rng_a = Rng::new(77);
    let mut rng_b = Rng::new(77);

    let counts_a = inproc.gather_counts(0).unwrap();
    let counts_b = tcp.gather_counts(0).unwrap();
    assert_eq!(counts_a, counts_b, "metadata snapshots must agree");

    let plan_a = sampler.plan(&counts_a, 7, &mut rng_a);
    let plan_b = sampler.plan(&counts_b, 7, &mut rng_b);
    assert_eq!(plan_a, plan_b, "same seed + same counts => same plan");

    let (rows_a, _) = sampler.execute(&inproc, &plan_a).unwrap();
    let (rows_b, _) = sampler.execute(&tcp, &plan_b).unwrap();

    // Byte-identical samples: labels equal, features bit-for-bit equal.
    assert_eq!(rows_a.len(), rows_b.len());
    for (a, b) in rows_a.iter().zip(&rows_b) {
        assert_eq!(a.label, b.label);
        let abits: Vec<u32> = a.features.iter().map(|f| f.to_bits()).collect();
        let bbits: Vec<u32> = b.features.iter().map(|f| f.to_bits()).collect();
        assert_eq!(abits, bbits, "features must survive the wire bit-exact");
    }

    // RPC counts are a property of the plan, not the backend.
    let (rpcs_a, bytes_a, meta_a, meta_bytes_a, _) = inproc.counters.snapshot();
    let (rpcs_b, bytes_b, meta_b, meta_bytes_b, _) = tcp.counters.snapshot();
    assert_eq!(rpcs_a, rpcs_b, "bulk RPC count must not depend on backend");
    assert_eq!(meta_a, meta_b, "meta RPC count must not depend on backend");
    assert_eq!(rpcs_a, plan_a.remote_rpcs(0) as u64);

    // Metadata bytes: semantic entry size on inproc (2 remote peers × 4
    // classes × 12 B), encoded exchange frames on tcp.
    assert_eq!(meta_bytes_a, 2 * 4 * 12);
    assert_eq!(meta_bytes_b,
               2 * wire::gather_counts_exchange_bytes(4) as u64);

    // Wire bytes differ by exactly the framing overhead: inproc accounts
    // the semantic payload (rows + the piggybacked snapshot at 12 B per
    // entry), tcp the encoded request+response frames (snapshot section
    // included).
    let mut semantic = 0u64;
    let mut framed = 0u64;
    for (target, picks) in &plan_a.requests {
        if *target == 0 || picks.is_empty() {
            continue;
        }
        let rows = bufs[*target].fetch_rows(picks).unwrap();
        let meta_entries = bufs[*target].snapshot_counts().len();
        semantic += (rows.iter().map(Sample::wire_bytes).sum::<usize>()
                     + meta_entries * 12) as u64;
        framed += wire::fetch_bulk_exchange_bytes(picks.len(), &rows,
                                                  meta_entries) as u64;
    }
    assert_eq!(bytes_a, semantic, "inproc bytes = semantic payload");
    assert_eq!(bytes_b, framed, "tcp bytes = encoded frame sizes");
    assert!(bytes_b > bytes_a, "framing overhead must be visible");

    // Virtual wire time is priced identically on both backends.
    let wire_a = inproc.counters.wire_ns.load(Ordering::Relaxed);
    let wire_b = tcp.counters.wire_ns.load(Ordering::Relaxed);
    assert_eq!(wire_a, wire_b, "virtual pricing must be backend-independent");

    tcp.shutdown().unwrap();
}

/// Drive `iters` iterations of the same deterministic batch stream through
/// a 2-worker cluster over the given backend and return per-class
/// occupancy (the engine-parity harness, parameterised by transport).
fn run_mode(kind: TransportKind, iters: u32) -> Vec<Vec<(u32, usize)>> {
    let (b, r) = (8usize, 4usize);
    let buffers = (0..2)
        .map(|w| Arc::new(LocalBuffer::new(60, PolicyKind::Uniform, w as u64)))
        .collect();
    let fabric = Arc::new(
        Fabric::for_kind(kind, buffers, CostModel::default(), false).unwrap());
    let params = EngineParams {
        batch: b,
        reps: r,
        candidates: b, // c = b: occupancy independent of RNG interleaving
        scope: SamplingScope::Global,
        async_updates: true,
    };
    let mut engines: Vec<RehearsalEngine> = (0..2)
        .map(|w| RehearsalEngine::new(w, Arc::clone(&fabric), params,
                                      1000 + w as u64))
        .collect();
    for i in 0..iters {
        for (w, e) in engines.iter_mut().enumerate() {
            let class = (w as u32 * 5 + i) % 7;
            let batch = Batch::new(
                (0..b).map(|j| Sample::new(class, vec![i as f32, j as f32]))
                    .collect());
            e.update(&batch).unwrap();
        }
    }
    for e in &mut engines {
        e.shutdown().unwrap();
    }
    drop(engines);
    let occupancy = (0..2).map(|w| fabric.buffer(w).snapshot_counts()).collect();
    fabric.shutdown().unwrap();
    occupancy
}

#[test]
fn deterministic_candidate_stream_occupancy_is_backend_identical() {
    let inproc = run_mode(TransportKind::Inproc, 40);
    let tcp = run_mode(TransportKind::Tcp, 40);
    assert_eq!(inproc, tcp,
               "TCP transport changed buffer contents, not just the wire");
    let total: usize = tcp.iter().flatten().map(|&(_, n)| n).sum();
    assert!(total > 0, "buffers stayed empty");
}

#[test]
fn rehearsal_training_run_completes_over_tcp_loopback() {
    let mut cfg = dcl::testkit::tiny_config().expect("tiny config");
    cfg.training.epochs_per_task = 1;
    cfg.training.strategy = Strategy::Rehearsal;
    cfg.cluster.transport = TransportKind::Tcp;
    assert!(cfg.cluster.workers >= 2, "needs real remote traffic");
    cfg.validate().unwrap();
    let report = run_experiment(&cfg).expect("tcp rehearsal run");
    assert_eq!(report.transport, "tcp");
    assert!(report.iterations > 0);
    assert!(report.epochs.iter().all(|e| e.train_loss.is_finite()));
}
