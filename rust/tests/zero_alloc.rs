//! Pins the PR-4/PR-5/PR-6 tentpole invariant: a steady-state training
//! iteration — flatten → blocked fwd/bwd (`train_step_with` /
//! `train_step_aug_with`) → `submit` → reduce → update — performs **zero
//! heap allocations** once the per-worker [`StepWorkspace`] and the
//! accumulator's reduce scratch are warm. All three reduce paths are
//! pinned: the sequential `reduce_with` + `apply_update_in`; the PR-5
//! chunk-parallel `reduce_chunk_with` + range-limited `apply_update_span`
//! (per-chunk scratch built once at accumulator construction, segment
//! walking allocation-free); and the PR-6 layer-streamed path
//! (`train_step_streamed_with` whose sink runs `submit_bucket` +
//! `fold_ready` per bucket — per-bucket readiness counters and per-region
//! fold guards are all preallocated at accumulator construction).
//!
//! PR 7 additions on the measured path: the kernel **ISA dispatch**
//! (`active_isa` reads `DCL_KERNEL_ISA` once — that one allocating env
//! read is forced during warm-up, after which dispatch is a relaxed
//! atomic load per GEMM) and **worker CPU pinning**
//! (`affinity::pin_current_thread` is called inside the measured loop:
//! the raw-syscall success path must stay heap-free so the trainer can
//! pin without moving the zero-alloc pin).
//!
//! Mechanism: a counting `#[global_allocator]` wrapping `System`. This
//! file deliberately holds a single `#[test]` so no sibling test thread
//! can allocate inside the measurement window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use dcl::cluster::GradAccumulator;
use dcl::net::CostModel;
use dcl::runtime::affinity;
use dcl::runtime::kernels;
use dcl::runtime::{Literal, Manifest, ModelExecutor};
use dcl::tensor::{Batch, Sample};
use dcl::util::rng::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn batch(dim: usize, classes: usize, rows: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    Batch::new((0..rows).map(|_| {
        Sample::new(rng.below(classes) as u32,
                    (0..dim).map(|_| rng.normal() as f32 * 0.5).collect())
    }).collect())
}

#[test]
fn steady_state_train_iteration_allocates_nothing() {
    // Small input dim keeps the test fast; the layer stack (512, 256)
    // still exercises every kernel path, including edge tiles.
    let (dim, classes, b, r) = (64usize, 8usize, 8usize, 2usize);
    let m = Manifest::synthetic(dim, classes, b, vec![r], 10);
    let exec = ModelExecutor::new(&m, "resnet18_sim", &[r]).unwrap();
    let (mut params, mut moms) = exec.init_state().unwrap();
    let shapes: Vec<Vec<usize>> =
        exec.meta.params.iter().map(|p| p.shape.clone()).collect();
    let acc = GradAccumulator::with_workers(shapes.clone(), 1);
    // Chunk-parallel accumulator: C = 3 over this model's parameter count
    // divides nothing, so chunks cross tensor boundaries and the segment
    // walk is exercised; one worker legally owns every chunk.
    let acc_c = GradAccumulator::with_chunks(shapes.clone(), 1, 3);
    // Streamed accumulator: same geometry, fed bucket-by-bucket from the
    // backward sink with eager folds (N = 1, so every bucket is ready the
    // moment this worker submits it).
    let acc_s = GradAccumulator::with_chunks(shapes, 1, 3);
    let cost = CostModel::default();
    let mut ws = exec.make_workspace();
    let plain = batch(dim, classes, b, 1);
    let aug_b = batch(dim, classes, b, 2);
    let reps = batch(dim, classes, r, 3);

    let one_iteration = |params: &mut Vec<_>, moms: &mut Vec<_>,
                         ws: &mut dcl::runtime::StepWorkspace,
                         augmented: bool| {
        let stats = if augmented {
            exec.train_step_aug_with(params, &aug_b, &reps, ws).unwrap()
        } else {
            exec.train_step_with(params, &plain, ws).unwrap()
        };
        assert!(stats.loss.is_finite());
        acc.submit(0, ws.grads()).unwrap();
        acc.reduce_with(&cost, |mean, _wire| {
            exec.apply_update_in(params, moms, mean, 0.05)
        }).unwrap();
    };

    // Same iteration through the chunk-parallel protocol: fold owned
    // chunks (all of them, worker 0 of 1) + range-limited fused update
    // per segment, then retire the slot.
    let chunk_iteration = |params: &mut Vec<Literal>, moms: &mut Vec<Literal>,
                           ws: &mut dcl::runtime::StepWorkspace,
                           augmented: bool| {
        let stats = if augmented {
            exec.train_step_aug_with(params, &aug_b, &reps, ws).unwrap()
        } else {
            exec.train_step_with(params, &plain, ws).unwrap()
        };
        assert!(stats.loss.is_finite());
        acc_c.submit(0, ws.grads()).unwrap();
        let replicas = acc_c.replicas();
        let plan = acc_c.plan();
        for chunk in plan.owned_by(0) {
            acc_c.reduce_chunk_with(chunk, replicas, |mean| {
                for seg in plan.segments(chunk) {
                    let g = &mean[seg.chunk_off..seg.chunk_off + seg.len()];
                    let decay = params[seg.tensor].shape().len() > 1;
                    exec.apply_update_span(
                        &mut params[seg.tensor].data_mut()[seg.start..seg.end],
                        &mut moms[seg.tensor].data_mut()[seg.start..seg.end],
                        g, decay, 0.05);
                }
                Ok(())
            }).unwrap();
        }
        acc_c.end_round(0).unwrap();
    };

    // The PR-6 layer-streamed iteration: backward's sink submits each
    // (dW, db) bucket and eagerly folds the regions it completed, then
    // the finish path publishes the (already-folded) chunks and applies
    // the fused update per segment.
    let streamed_iteration = |params: &mut Vec<Literal>,
                              moms: &mut Vec<Literal>,
                              ws: &mut dcl::runtime::StepWorkspace,
                              augmented: bool| {
        let stats = {
            let mut sink = |b: usize, g: &[Literal]| -> anyhow::Result<()> {
                acc_s.submit_bucket(0, b, g)?;
                acc_s.fold_ready(0)?;
                Ok(())
            };
            if augmented {
                exec.train_step_aug_streamed_with(params, &aug_b, &reps, ws,
                                                  &mut sink).unwrap()
            } else {
                exec.train_step_streamed_with(params, &plain, ws, &mut sink)
                    .unwrap()
            }
        };
        assert!(stats.loss.is_finite());
        let replicas = acc_s.replicas();
        let plan = acc_s.plan();
        for chunk in plan.owned_by(0) {
            acc_s.reduce_chunk_with(chunk, replicas, |mean| {
                for seg in plan.segments(chunk) {
                    let g = &mean[seg.chunk_off..seg.chunk_off + seg.len()];
                    let decay = params[seg.tensor].shape().len() > 1;
                    exec.apply_update_span(
                        &mut params[seg.tensor].data_mut()[seg.start..seg.end],
                        &mut moms[seg.tensor].data_mut()[seg.start..seg.end],
                        g, decay, 0.05);
                }
                Ok(())
            }).unwrap();
        }
        acc_s.end_round(0).unwrap();
    };

    // Warm-up: first touches may fault in lazily-initialised runtime
    // state (timer calibration, lock shadows) besides filling the
    // workspace slabs and the accumulators' scratch. The ISA dispatch
    // cache is primed explicitly — its one-time `DCL_KERNEL_ISA` env read
    // allocates, and must never land in the measured window.
    let isa = kernels::active_isa();
    for i in 0..3 {
        one_iteration(&mut params, &mut moms, &mut ws, i % 2 == 1);
        chunk_iteration(&mut params, &mut moms, &mut ws, i % 2 == 0);
        streamed_iteration(&mut params, &mut moms, &mut ws, i % 2 == 1);
    }

    let slab0 = ws.grads()[0].data().as_ptr() as usize;
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 0..10 {
        // Re-querying the dispatch and re-pinning the thread both sit on
        // the measured path: dispatch must be a cached atomic load, and
        // the pin syscall's success path must stay off the heap (the
        // trainer pins pinned-worker runs before its first barrier).
        assert_eq!(kernels::active_isa(), isa);
        affinity::pin_current_thread(i).unwrap();
        one_iteration(&mut params, &mut moms, &mut ws, i % 2 == 1);
        chunk_iteration(&mut params, &mut moms, &mut ws, i % 2 == 0);
        streamed_iteration(&mut params, &mut moms, &mut ws, i % 2 == 1);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(after - before, 0,
               "steady-state train iterations (sequential + chunked + \
                streamed reduce + isa dispatch + thread pinning) must not \
                allocate ({} allocator calls in 10 iterations)",
               after - before);
    assert_eq!(ws.grads()[0].data().as_ptr() as usize, slab0,
               "gradient slab moved despite zero allocations");
}
