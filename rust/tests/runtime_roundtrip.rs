//! Integration: PJRT runtime loads the AOT artifacts and the numerics agree
//! with the Python reference semantics (loss ≈ ln K at init, counts sane,
//! fused update moves parameters as SGD should). Skipped when artifacts are
//! missing (run `make artifacts` first).

use dcl::runtime::{Manifest, ModelExecutor};
use dcl::runtime::executor::literal_to_vec;
use dcl::tensor::{Batch, Sample};
use dcl::testkit;
use dcl::util::rng::Rng;

fn make_batch(rows: usize, dim: usize, classes: u32, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let samples = (0..rows)
        .map(|_| {
            let feats: Vec<f32> =
                (0..dim).map(|_| rng.normal() as f32 * 0.5).collect();
            Sample::new(rng.below(classes as usize) as u32, feats)
        })
        .collect();
    Batch::new(samples)
}

fn setup() -> Option<(Manifest, ModelExecutor)> {
    let dir = testkit::tiny_artifacts_dir()?;
    let manifest = Manifest::load(&dir).expect("manifest");
    let exec = ModelExecutor::new(&manifest, "resnet18_sim", &[2]).expect("compile");
    Some((manifest, exec))
}

#[test]
fn initial_loss_is_log_k() {
    let Some((m, exec)) = setup() else { return };
    let (params, _) = exec.init_state().unwrap();
    let batch = make_batch(m.batch, m.input_dim, m.num_classes as u32, 1);
    let out = exec.train_step(&params, &batch).unwrap();
    // biases are zero and weights He-random: logits are ~centered, so loss
    // should be close to ln(K) = ln 8 ≈ 2.079
    let lnk = (m.num_classes as f32).ln();
    assert!((out.loss - lnk).abs() < 0.8, "loss {} vs lnK {}", out.loss, lnk);
    assert!(out.top1 <= out.top5);
    assert!(out.top5 <= m.batch as f32);
    assert_eq!(out.grads.len(), exec.meta.params.len());
}

#[test]
fn augmented_step_equals_concat_semantics() {
    let Some((m, exec)) = setup() else { return };
    let (params, _) = exec.init_state().unwrap();
    let batch = make_batch(m.batch, m.input_dim, m.num_classes as u32, 2);
    let reps = make_batch(2, m.input_dim, m.num_classes as u32, 3);
    let out = exec.train_step_aug(&params, &batch, &reps).unwrap();
    assert!(out.loss.is_finite());
    assert!(out.top5 <= (m.batch + 2) as f32);
    // gradients must differ from the plain step (more rows)
    let plain = exec.train_step(&params, &batch).unwrap();
    let g_aug = literal_to_vec(&out.grads[0]).unwrap();
    let g_plain = literal_to_vec(&plain.grads[0]).unwrap();
    assert_ne!(g_aug, g_plain);
}

#[test]
fn fused_update_is_sgd_with_momentum() {
    let Some((m, exec)) = setup() else { return };
    let (params, moms) = exec.init_state().unwrap();
    let batch = make_batch(m.batch, m.input_dim, m.num_classes as u32, 4);
    let out = exec.train_step(&params, &batch).unwrap();

    let p0 = literal_to_vec(&params[0]).unwrap();
    let g0 = literal_to_vec(&out.grads[0]).unwrap();
    let lr = 0.05f32;
    let (new_params, new_moms) = exec
        .apply_update(params, moms, &out.grads, lr as f64)
        .unwrap();
    let p1 = literal_to_vec(&new_params[0]).unwrap();
    let m1 = literal_to_vec(&new_moms[0]).unwrap();
    // first step, zero momentum: m' = g + wd*w ; w' = w - lr*m'
    let wd = exec.meta.weight_decay as f32;
    for i in (0..p0.len()).step_by(997) {
        let expect_m = g0[i] + wd * p0[i];
        let expect_p = p0[i] - lr * expect_m;
        assert!((m1[i] - expect_m).abs() < 1e-5, "mom[{i}]");
        assert!((p1[i] - expect_p).abs() < 1e-5, "param[{i}]");
    }
}

#[test]
fn eval_step_counts_are_bounded() {
    let Some((m, exec)) = setup() else { return };
    let (params, _) = exec.init_state().unwrap();
    let batch = make_batch(m.eval_batch, m.input_dim, m.num_classes as u32, 5);
    let (loss_sum, top1, top5) = exec.eval_step(&params, &batch).unwrap();
    assert!(loss_sum.is_finite() && loss_sum > 0.0);
    assert!(top1 >= 0.0 && top1 <= top5 && top5 <= m.eval_batch as f32);
}

#[test]
fn no_memory_leak_across_steps() {
    // Regression: the xla crate's `execute` leaks every input device buffer
    // (~70 MB per resnet50 step); our executor must hold RSS flat. This
    // originally OOM-killed whole experiment harnesses.
    fn rss_kb() -> i64 {
        std::fs::read_to_string("/proc/self/status")
            .ok()
            .and_then(|s| {
                s.lines()
                    .find(|l| l.starts_with("VmRSS"))
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|v| v.parse().ok())
            })
            .unwrap_or(0)
    }
    let Some((m, exec)) = setup() else { return };
    let (mut params, mut moms) = exec.init_state().unwrap();
    let batch = make_batch(m.batch, m.input_dim, m.num_classes as u32, 7);
    // warm up allocator pools
    for _ in 0..3 {
        let out = exec.train_step(&params, &batch).unwrap();
        let (p, mm) = exec.apply_update(params, moms, &out.grads, 0.01).unwrap();
        params = p;
        moms = mm;
    }
    let before = rss_kb();
    for _ in 0..15 {
        let out = exec.train_step(&params, &batch).unwrap();
        let (p, mm) = exec.apply_update(params, moms, &out.grads, 0.01).unwrap();
        params = p;
        moms = mm;
    }
    let grown_mb = (rss_kb() - before) as f64 / 1024.0;
    // tiny model: params ~7 MB host-side; leaking inputs would grow
    // >200 MB here. Allow generous allocator slack.
    assert!(grown_mb < 80.0, "RSS grew {grown_mb:.0} MB over 15 steps");
}

#[test]
fn training_reduces_loss_on_fixed_batch() {
    let Some((m, exec)) = setup() else { return };
    let (mut params, mut moms) = exec.init_state().unwrap();
    let batch = make_batch(m.batch, m.input_dim, m.num_classes as u32, 6);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let out = exec.train_step(&params, &batch).unwrap();
        first.get_or_insert(out.loss);
        last = out.loss;
        let (p, mm) = exec
            .apply_update(params, moms, &out.grads, 0.05)
            .unwrap();
        params = p;
        moms = mm;
    }
    let first = first.unwrap();
    assert!(last < first * 0.8, "loss {first} -> {last}");
}
