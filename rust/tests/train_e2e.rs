//! End-to-end integration: all three strategies run to completion on the
//! tiny geometry, and the paper's qualitative ordering holds —
//! incremental training forgets earlier tasks; rehearsal recovers much of
//! the gap. (Top-*1* is asserted here: with K=8 classes top-5 chance level
//! is 62.5 %, too coarse for a tiny smoke test.)
//!
//! Skipped when `make artifacts` has not produced artifacts/tiny.

use dcl::config::Strategy;
use dcl::train::trainer::run_experiment;

#[test]
fn rehearsal_beats_incremental_and_runs_clean() {
    let Some(mut cfg) = dcl::testkit::tiny_config() else { return };
    cfg.training.epochs_per_task = 3;
    cfg.buffer.percent_of_dataset = 30.0;
    cfg.validate().unwrap();

    cfg.training.strategy = Strategy::Incremental;
    let inc = run_experiment(&cfg).expect("incremental run");

    cfg.training.strategy = Strategy::Rehearsal;
    let reh = run_experiment(&cfg).expect("rehearsal run");

    // Every epoch recorded, evals present at task boundaries.
    let total_epochs = cfg.data.num_tasks * cfg.training.epochs_per_task;
    assert_eq!(inc.epochs.len(), total_epochs);
    assert_eq!(reh.epochs.len(), total_epochs);
    assert!(inc.epochs.last().unwrap().eval.is_some());

    // Catastrophic forgetting: incremental's final per-task accuracy on the
    // FIRST task should be far below its accuracy on the LAST task.
    let inc_eval = inc.epochs.last().unwrap().eval.clone().unwrap();
    let first_task = inc_eval.per_task_top1.first().copied().unwrap();
    let last_task = inc_eval.per_task_top1.last().copied().unwrap();
    assert!(last_task > first_task + 0.05,
            "no forgetting signal: first {first_task}, last {last_task}");

    // Rehearsal recovers accuracy over incremental (top-1, Eq. 1).
    assert!(reh.final_top1_accuracy_t > inc.final_top1_accuracy_t,
            "rehearsal {} <= incremental {}",
            reh.final_top1_accuracy_t, inc.final_top1_accuracy_t);

    // Rehearsal metadata is recorded.
    assert!(reh.background_ms.0 > 0.0 || reh.background_ms.1 > 0.0,
            "engine timings empty");
    assert!(reh.train_step_ms > 0.0);
    assert!(reh.allreduce_bytes > 0);
}

#[test]
fn from_scratch_is_upper_bound_and_slowest() {
    let Some(mut cfg) = dcl::testkit::tiny_config() else { return };
    cfg.training.epochs_per_task = 2;

    cfg.training.strategy = Strategy::FromScratch;
    let scratch = run_experiment(&cfg).expect("scratch run");
    cfg.training.strategy = Strategy::Incremental;
    let inc = run_experiment(&cfg).expect("incremental run");

    // From-scratch sees all accumulated data: per-epoch wall time of the
    // last task must exceed the first task's (quadratic growth signal).
    let first_epoch = scratch.epochs.first().unwrap().wall.as_secs_f64();
    let last_epoch = scratch.epochs.last().unwrap().wall.as_secs_f64();
    assert!(last_epoch > 1.5 * first_epoch,
            "no quadratic-growth signal: {first_epoch} vs {last_epoch}");

    // And beats incremental on accuracy over all tasks.
    assert!(scratch.final_top1_accuracy_t > inc.final_top1_accuracy_t,
            "scratch {} <= incremental {}",
            scratch.final_top1_accuracy_t, inc.final_top1_accuracy_t);
}

#[test]
fn blocking_engine_matches_async_quality() {
    // The async pipeline is a performance optimisation; accuracy must be
    // unaffected (same sampling distribution, one-iteration-stale reps).
    let Some(mut cfg) = dcl::testkit::tiny_config() else { return };
    cfg.training.epochs_per_task = 2;
    cfg.training.strategy = Strategy::Rehearsal;

    cfg.buffer.async_updates = true;
    let async_run = run_experiment(&cfg).expect("async");
    cfg.buffer.async_updates = false;
    let blocking = run_experiment(&cfg).expect("blocking");

    let diff = (async_run.final_top1_accuracy_t - blocking.final_top1_accuracy_t).abs();
    assert!(diff < 0.25, "async {} vs blocking {}",
            async_run.final_top1_accuracy_t, blocking.final_top1_accuracy_t);
}
