//! Async/blocking engine parity and zero-copy accounting invariants
//! (DESIGN.md abl-async):
//!
//! - with a fixed seed, `emulate_delays = false` and a deterministic
//!   candidate stream (c = b so Algorithm 1 offers every sample), the async
//!   and blocking engines must leave **identical per-class buffer
//!   occupancy** — the pipeline is a scheduling optimisation, not a
//!   different sampling distribution;
//! - the `Arc<[f32]>` zero-copy sample refactor must not change what the
//!   fabric *accounts*: `fetch_bulk` wire bytes stay `4·d + 8` per row, and
//!   fetched rows share storage with the buffer instead of copying it.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dcl::buffer::LocalBuffer;
use dcl::config::{PolicyKind, SamplingScope};
use dcl::engine::{EngineParams, RehearsalEngine};
use dcl::net::{CostModel, Fabric};
use dcl::tensor::{Batch, Sample};

fn make_fabric(n: usize, s_max: usize) -> Arc<Fabric> {
    let buffers = (0..n)
        .map(|w| Arc::new(LocalBuffer::new(s_max, PolicyKind::Uniform, w as u64)))
        .collect();
    Arc::new(Fabric::new(buffers, CostModel::default(), false))
}

fn params(async_updates: bool, b: usize, r: usize) -> EngineParams {
    EngineParams {
        batch: b,
        reps: r,
        // c = b: every sample is offered, so occupancy is independent of
        // the engines' candidate-draw RNG streams (which differ between
        // the async and blocking code paths by design).
        candidates: b,
        scope: SamplingScope::Global,
        async_updates,
    }
}

/// Drive `iters` iterations of the same deterministic batch stream through
/// a 2-worker cluster and return each worker's per-class occupancy.
fn run_mode(async_updates: bool, iters: u32) -> Vec<Vec<(u32, usize)>> {
    let (b, r) = (8usize, 4usize);
    let fabric = make_fabric(2, 60);
    let mut engines: Vec<RehearsalEngine> = (0..2)
        .map(|w| RehearsalEngine::new(w, Arc::clone(&fabric),
                                      params(async_updates, b, r), 1000 + w as u64))
        .collect();
    for i in 0..iters {
        for (w, e) in engines.iter_mut().enumerate() {
            // worker-distinct, iteration-varying classes; same stream in
            // both modes.
            let class = (w as u32 * 5 + i) % 7;
            let batch = Batch::new(
                (0..b).map(|j| Sample::new(class, vec![i as f32, j as f32])).collect());
            e.update(&batch).unwrap();
        }
    }
    for e in &mut engines {
        e.finish().unwrap();
    }
    drop(engines); // join background threads before reading occupancy
    (0..2).map(|w| fabric.buffer(w).snapshot_counts()).collect()
}

#[test]
fn async_and_blocking_reach_identical_occupancy() {
    let async_counts = run_mode(true, 40);
    let blocking_counts = run_mode(false, 40);
    assert_eq!(async_counts, blocking_counts,
               "async pipeline changed buffer contents, not just timing");
    // sanity: the run actually filled the buffers
    let total: usize = async_counts.iter().flatten().map(|&(_, n)| n).sum();
    assert!(total > 0, "buffers stayed empty");
    for counts in &async_counts {
        let sum: usize = counts.iter().map(|&(_, n)| n).sum();
        assert!(sum <= 60, "S_max exceeded: {sum}");
    }
}

#[test]
fn fetch_bulk_wire_bytes_formula_is_unchanged() {
    // d=8 features: every row must be charged 8*4 + 8 = 40 wire bytes,
    // plus 12 semantic bytes per entry of the metadata snapshot that the
    // bounded-staleness plane piggybacks on every remote fetch (one class
    // resident on the target here).
    let d = 8usize;
    let fabric = make_fabric(2, 100);
    for i in 0..10 {
        fabric.buffer(1).insert(Sample::new(3, vec![i as f32; 8]));
    }
    let picks: Vec<(u32, usize)> = (0..6).map(|i| (3u32, i)).collect();
    let (rows, wire) = fabric.fetch_bulk(0, 1, &picks).unwrap();
    assert_eq!(rows.len(), 6);
    assert_eq!(fabric.counters.bytes.load(Ordering::Relaxed),
               (6 * (d * 4 + 8) + 12) as u64);
    assert_eq!(rows.iter().map(Sample::wire_bytes).sum::<usize>(), 6 * 40);
    assert!(wire > std::time::Duration::ZERO);
    assert_eq!(fabric.counters.rpcs.load(Ordering::Relaxed), 1);

    // local fetch stays free on the wire
    let before = fabric.counters.bytes.load(Ordering::Relaxed);
    let (_rows, wire) = fabric.fetch_bulk(1, 1, &picks).unwrap();
    assert!(wire.is_zero());
    assert_eq!(fabric.counters.bytes.load(Ordering::Relaxed), before);
}

#[test]
fn fetched_rows_share_storage_with_the_buffer() {
    // Two fetches of the same resident must hand back the same Arc slab —
    // the zero-copy property the refactor introduced.
    let fabric = make_fabric(1, 100);
    fabric.buffer(0).insert(Sample::new(0, vec![1.0, 2.0, 3.0]));
    let a = fabric.fetch_bulk(0, 0, &[(0, 0)]).unwrap().0.remove(0);
    let b = fabric.fetch_bulk(0, 0, &[(0, 0)]).unwrap().0.remove(0);
    assert!(Arc::ptr_eq(&a.features, &b.features),
            "fetch_rows deep-copied the features instead of sharing them");
}
