//! Seeded fault-injection suite for the elastic fault domain.
//!
//! Four scenarios the unit tests cannot cover end-to-end:
//!
//! 1. a peer dying mid-run under `elastic = true` — the run must complete
//!    with the degradation *counted* (never silent) and the loss committed
//!    by the membership plane at an epoch boundary;
//! 2. the live recovery tentpole: after the commit the trainer swaps the
//!    run onto the survivor-count plan in place (retired worker thread,
//!    re-armed reduce plane, rebalanced rehearsal buffers) and forces a
//!    commit-point snapshot — the degraded tail must be bit-identical to
//!    a fresh survivor-count run resumed from that snapshot, and the
//!    degraded-fetch tally must be confined to the pre-commit window;
//! 3. checkpoint → kill → resume at workers = 1 — the resumed run must be
//!    bit-identical to an uninterrupted one, and the snapshot itself must
//!    be byte-deterministic (same seed → same file bytes), which is what
//!    makes the atomic-rename publish equivalent to surviving a real kill;
//! 4. a corrupted or truncated snapshot — resume must fail with a clean
//!    error (CRC/magic/truncation named), never a panic or a wild alloc.
//!
//! All faults come from `[cluster] fault_plan`, a seeded test-only
//! schedule, so every scenario replays identically under the same seed.

use std::path::PathBuf;

use dcl::ckpt::Checkpoint;
use dcl::config::{ExperimentConfig, Strategy};
use dcl::train::trainer::run_experiment;

/// Tiny 2-task geometry shared by all scenarios (synthetic manifest when
/// the AOT artifacts are absent, same as the trainer's own e2e tests).
fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = dcl::testkit::tiny_config().expect("tiny preset");
    cfg.training.epochs_per_task = 1;
    cfg.data.num_tasks = 2;
    cfg.data.num_classes = 8;
    cfg.training.strategy = Strategy::Rehearsal;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dcl-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn elastic_run_survives_peer_death_and_counts_it() {
    // Worker 1's transport endpoint is dead from the very first remote op.
    // Elastic mode: every failed fetch/gather falls back to the local-only
    // view (counted as degraded), strikes accrue, and the membership plane
    // commits the loss at the next epoch boundary. The run completes.
    let mut cfg = tiny_cfg();
    cfg.cluster.workers = 3;
    cfg.cluster.elastic = true;
    cfg.cluster.fault_plan = "kill:1@0".to_string();
    cfg.validate().unwrap();
    let report = run_experiment(&cfg).expect(
        "elastic run must survive a dead rehearsal peer");
    assert!(report.iterations > 0);
    assert!(report.epochs.iter().all(|e| e.train_loss.is_finite()),
            "degraded rehearsal must still train");
    assert!(report.degraded_fetches > 0,
            "fallbacks to the local-only view must be counted, not silent");
    assert_eq!(report.lost_workers, 1,
               "peer 1 must be committed lost at an epoch boundary");
    // the degradation is visible in the human summary line too
    let line = dcl::experiments::common::summarize(&report);
    assert!(line.contains("degraded"),
            "summary must say the run was degraded: {line}");

    // Same fault without elastic mode: the error must propagate (the
    // pre-elastic contract), not be silently absorbed.
    let mut cfg = tiny_cfg();
    cfg.cluster.workers = 3;
    cfg.cluster.fault_plan = "kill:1@0".to_string();
    cfg.validate().unwrap();
    let err = run_experiment(&cfg).expect_err(
        "non-elastic run must fail when a peer dies");
    let chain = format!("{err:#}");
    assert!(!chain.is_empty());
}

#[test]
fn lost_worker_is_recovered_by_a_live_plan_swap() {
    // The recovery tentpole, end to end: a 4-worker elastic run loses
    // peer 1 (dead transport endpoint from op 0). At the commit boundary
    // the trainer swaps onto the 3-survivor plan in place — retires the
    // worker thread, re-arms the reduce plane, rebalances the buffers —
    // and forces a commit-point snapshot. The degraded tail must then be
    // bit-identical to a fresh 3-worker run resumed from that snapshot.
    let dir = tmp_dir("swap");
    let mut cfg = tiny_cfg();
    cfg.cluster.workers = 4;
    cfg.cluster.elastic = true;
    cfg.cluster.fault_plan = "kill:1@0".to_string();
    cfg.training.epochs_per_task = 2; // 4 boundaries; commit lands early
    cfg.training.ckpt_dir = Some(dir.clone());
    cfg.training.ckpt_every_iters = usize::MAX; // only the commit saves
    cfg.validate().unwrap();
    let a = run_experiment(&cfg)
        .expect("elastic run must recover from a lost worker");
    assert_eq!(a.lost_workers, 1, "peer 1 must be committed lost");
    assert!(a.degraded_fetches > 0,
            "the pre-commit window must be counted as degraded");

    // The forced commit-point snapshot is the recovery anchor: it records
    // the launch topology AND the survivor count, with dense per-survivor
    // records and the membership plane riding along. Its degraded tally
    // already equals the whole run's: after the swap the dead peer is
    // skipped silently, so no degraded fetch may happen post-commit.
    let ck = Checkpoint::load(&dir).expect("commit-point snapshot");
    assert_eq!(ck.workers, 4, "launch topology is preserved");
    assert_eq!(ck.active(), 3, "snapshot must carry the survivor count");
    assert_eq!(ck.membership.lost, vec![1]);
    assert_eq!(ck.buffers.len(), 3, "survivor records are dense");
    assert_eq!(ck.worker_state.len(), 3);
    assert!((ck.global_epoch as usize) < a.epochs.len(),
            "the commit must leave a post-swap tail, got epoch {}",
            ck.global_epoch);
    assert_eq!(ck.fabric[5], a.degraded_fetches,
               "degraded fetches must be confined to the pre-commit window");

    // Resume the snapshot as a fresh, dense 3-worker run (no fault plan,
    // no dead peer): its replay of the post-commit epochs must match run
    // A's live degraded tail bit for bit — proof the swap really put the
    // run onto the 3-worker plan (shards, loader seeds, chunk plan, LR
    // scale and buffer capacity all included).
    let mut cfg_r = cfg.clone();
    cfg_r.cluster.workers = 3;
    cfg_r.cluster.fault_plan = String::new();
    cfg_r.training.resume = true;
    cfg_r.validate().unwrap();
    let r = run_experiment(&cfg_r).expect("degraded resume");
    assert_eq!(r.lost_workers, 0, "the resumed dense run is healthy");
    assert_eq!(a.iterations, r.iterations,
               "resume restores the iteration cursor");
    assert_eq!(a.final_accuracy_t, r.final_accuracy_t);
    assert_eq!(a.final_top1_accuracy_t, r.final_top1_accuracy_t);
    let tail: Vec<_> = a.epochs.iter()
        .filter(|e| e.epoch >= ck.global_epoch as usize).collect();
    assert_eq!(r.epochs.len(), tail.len());
    for (er, ea) in r.epochs.iter().zip(tail) {
        assert_eq!(er.epoch, ea.epoch);
        assert_eq!(er.train_loss, ea.train_loss,
                   "epoch {} diverged from the live swap", er.epoch);
        assert_eq!(er.train_top5, ea.train_top5);
    }

    // Resuming a degraded snapshot at the launch count is refused with
    // actionable advice, never a mis-shaped restore.
    let mut cfg_w = cfg.clone();
    cfg_w.cluster.fault_plan = String::new();
    cfg_w.training.resume = true;
    cfg_w.validate().unwrap();
    let err = run_experiment(&cfg_w)
        .expect_err("a 4-worker resume of a 3-survivor snapshot");
    let chain = format!("{err:#}");
    assert!(chain.contains("workers = 3"),
            "the error must name the right resume count: {chain}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn workers1_checkpoint_kill_resume_is_bit_identical() {
    // Run A: uninterrupted. Run B: checkpoints exactly once mid-run — its
    // post-snapshot work is then *discarded*, exactly what a kill after the
    // atomic publish would leave on disk. Run C: resumes from the snapshot
    // and must replay A's tail bit-for-bit.
    let dir = tmp_dir("resume");
    let mut cfg = tiny_cfg();
    cfg.cluster.workers = 1;
    cfg.training.epochs_per_task = 2; // 4 boundaries: cadence lands inside
    cfg.validate().unwrap();
    let a = run_experiment(&cfg).expect("uninterrupted run");

    let mut cfg_b = cfg.clone();
    cfg_b.training.ckpt_dir = Some(dir.clone());
    cfg_b.training.ckpt_every_iters = a.iterations / 2 + 1;
    cfg_b.validate().unwrap();
    let b = run_experiment(&cfg_b).expect("checkpointing run");
    assert_eq!(a.final_accuracy_t, b.final_accuracy_t,
               "checkpoint I/O must not perturb the run");
    let ck = Checkpoint::load(&dir).expect("published snapshot");
    assert!(ck.global_epoch > 0 && (ck.global_epoch as usize) < a.epochs.len(),
            "cadence must land the snapshot mid-run, got epoch {}",
            ck.global_epoch);

    // The snapshot is byte-deterministic: a second identically-seeded run
    // publishes the exact same file. Combined with write-to-temp + atomic
    // rename, this is why "the process was killed after the save" and "the
    // run went on to finish" leave indistinguishable snapshots.
    let dir2 = tmp_dir("resume-again");
    let mut cfg_b2 = cfg_b.clone();
    cfg_b2.training.ckpt_dir = Some(dir2.clone());
    run_experiment(&cfg_b2).expect("second checkpointing run");
    let bytes1 = std::fs::read(Checkpoint::path_in(&dir)).unwrap();
    let bytes2 = std::fs::read(Checkpoint::path_in(&dir2)).unwrap();
    assert_eq!(bytes1, bytes2, "snapshot bytes must be deterministic");
    std::fs::remove_dir_all(&dir2).unwrap();

    let mut cfg_c = cfg_b.clone();
    cfg_c.training.resume = true;
    cfg_c.validate().unwrap();
    let c = run_experiment(&cfg_c).expect("resumed run");
    assert_eq!(a.final_accuracy_t, c.final_accuracy_t);
    assert_eq!(a.final_top1_accuracy_t, c.final_top1_accuracy_t);
    assert_eq!(a.iterations, c.iterations,
               "resume restores the iteration cursor");
    let tail: Vec<_> = a.epochs.iter()
        .filter(|e| e.epoch >= ck.global_epoch as usize).collect();
    assert_eq!(c.epochs.len(), tail.len());
    for (ec, ea) in c.epochs.iter().zip(tail) {
        assert_eq!(ec.epoch, ea.epoch);
        assert_eq!(ec.train_loss, ea.train_loss,
                   "epoch {} diverged after kill/resume", ec.epoch);
        assert_eq!(ec.train_top5, ea.train_top5);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_or_truncated_checkpoint_is_rejected_cleanly() {
    // Publish a real snapshot, then resume from progressively mangled
    // copies of it. Every failure mode is a clean Err naming the defect —
    // no panic, no giant allocation, no half-restored run.
    let dir = tmp_dir("corrupt");
    let mut cfg = tiny_cfg();
    cfg.cluster.workers = 1;
    cfg.training.ckpt_dir = Some(dir.clone());
    cfg.training.ckpt_every_iters = 1; // save at every boundary
    cfg.validate().unwrap();
    run_experiment(&cfg).expect("checkpointing run");
    let path = Checkpoint::path_in(&dir);
    let good = std::fs::read(&path).unwrap();
    let mut resume_cfg = cfg.clone();
    resume_cfg.training.resume = true;
    resume_cfg.validate().unwrap();

    // flipped body bit -> CRC mismatch
    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x01;
    std::fs::write(&path, &bad).unwrap();
    let err = run_experiment(&resume_cfg).expect_err("corrupt snapshot");
    assert!(format!("{err:#}").contains("CRC"), "got: {err:#}");

    // truncated file -> clean truncation/length error
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    let err = run_experiment(&resume_cfg).expect_err("truncated snapshot");
    assert!(format!("{err:#}").contains("checkpoint"), "got: {err:#}");

    // not a checkpoint at all -> bad magic
    std::fs::write(&path, b"definitely not a checkpoint file").unwrap();
    let err = run_experiment(&resume_cfg).expect_err("garbage snapshot");
    assert!(format!("{err:#}").contains("magic"), "got: {err:#}");

    // and a missing file is an error too, not a silent fresh start
    std::fs::remove_file(&path).unwrap();
    let err = run_experiment(&resume_cfg).expect_err("missing snapshot");
    assert!(format!("{err:#}").contains("checkpoint"), "got: {err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}
