//! Property tests on global-sampling invariants (paper §IV-C / DESIGN.md
//! §5): without-replacement, consolidation, location-uniformity, and
//! local-scope containment — over randomized cluster geometries.

use dcl::config::SamplingScope;
use dcl::sampling::GlobalSampler;
use dcl::testkit::prop::{forall, usize_in};
use dcl::util::rng::Rng;
use dcl::util::stats::chi_square_uniform;

/// Random per-node per-class counts; some nodes may be empty.
fn random_counts(rng: &mut Rng) -> Vec<Vec<(u32, usize)>> {
    let workers = usize_in(rng, 1, 8);
    (0..workers)
        .map(|_| {
            let classes = usize_in(rng, 0, 6);
            (0..classes)
                .map(|c| (c as u32, usize_in(rng, 1, 15)))
                .collect()
        })
        .collect()
}

fn total(counts: &[Vec<(u32, usize)>]) -> usize {
    counts.iter().flatten().map(|&(_, n)| n).sum()
}

#[test]
fn plan_size_is_min_r_total() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let r = usize_in(rng, 0, 20);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, r, &mut prng);
        let expect = r.min(total(&counts));
        if plan.total != expect {
            return Err(format!("plan.total {} != {expect}", plan.total));
        }
        let n: usize = plan.requests.iter().map(|(_, p)| p.len()).sum();
        if n != expect {
            return Err(format!("picks {n} != {expect}"));
        }
        Ok(())
    });
}

#[test]
fn no_replacement_within_plan() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let r = usize_in(rng, 0, 25);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, r, &mut prng);
        for (w, picks) in &plan.requests {
            let mut seen = std::collections::HashSet::new();
            for &(c, i) in picks {
                if !seen.insert((c, i)) {
                    return Err(format!("duplicate pick ({c},{i}) at node {w}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn consolidation_one_request_per_node() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, usize_in(rng, 1, 20), &mut prng);
        let mut nodes: Vec<usize> = plan.requests.iter().map(|(w, _)| *w).collect();
        let len = nodes.len();
        nodes.sort_unstable();
        nodes.dedup();
        if nodes.len() != len {
            return Err("multiple requests for one node".into());
        }
        if nodes.iter().any(|&w| w >= counts.len()) {
            return Err("request to unknown node".into());
        }
        Ok(())
    });
}

#[test]
fn picks_respect_class_counts() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, usize_in(rng, 1, 20), &mut prng);
        for (w, picks) in &plan.requests {
            for &(c, i) in picks {
                let n = counts[*w]
                    .iter()
                    .find(|&&(cc, _)| cc == c)
                    .map(|&(_, n)| n)
                    .unwrap_or(0);
                if i >= n {
                    return Err(format!("pick ({c},{i}) beyond count {n} on node {w}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn local_scope_stays_local() {
    forall(60, |rng| {
        let counts = random_counts(rng);
        let me = rng.below(counts.len());
        let sampler = GlobalSampler::new(me, SamplingScope::LocalOnly);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, usize_in(rng, 1, 10), &mut prng);
        if plan.requests.iter().any(|(w, _)| *w != me) {
            return Err("local-only plan touched a remote node".into());
        }
        Ok(())
    });
}

#[test]
fn post_loss_plans_stay_uniform_over_survivors() {
    // Elastic loss commit, planner's view: the committed-lost peer serves
    // an empty count vector. Global picks must renormalize over the live
    // peers only — no request ever targets the dead node, the draw stays
    // χ²-uniform over the SURVIVING residents (the lost peer's classes,
    // still hosted on survivors, are not under-served), and the degraded
    // plan is bitwise the plan over the dense survivor-only geometry
    // (empty nodes are invisible to the flat pick space, so the live
    // post-swap run and a fresh survivor-count run draw identically).
    forall(4, |rng| {
        // 4-node geometry with node 1 committed lost. Classes 0 and 1
        // were hosted on the dead peer too; survivors still hold them.
        let c0 = usize_in(rng, 2, 6);
        let c1a = usize_in(rng, 2, 6);
        let c1b = usize_in(rng, 2, 6);
        let c2 = usize_in(rng, 2, 6);
        let degraded = vec![
            vec![(0u32, c0), (1, c1a)],
            vec![], // committed-lost peer: empty to the planner
            vec![(1u32, c1b)],
            vec![(2u32, c2)],
        ];
        let dense = vec![
            vec![(0u32, c0), (1, c1a)],
            vec![(1u32, c1b)],
            vec![(2u32, c2)],
        ];
        let tot = total(&degraded);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let seed = rng.next_u64();

        // Bitwise plan equivalence against the dense survivor geometry:
        // same RNG stream, node ids remapped 0,2,3 -> 0,1,2.
        let mut prng_a = Rng::new(seed);
        let mut prng_b = Rng::new(seed);
        for round in 0..50 {
            let pa = sampler.plan(&degraded, 3, &mut prng_a);
            let pb = sampler.plan(&dense, 3, &mut prng_b);
            if pa.total != pb.total
                || pa.requests.len() != pb.requests.len()
            {
                return Err(format!("round {round}: plan shapes diverged"));
            }
            for ((wa, la), (wb, lb)) in pa.requests.iter().zip(&pb.requests) {
                let wa = if *wa > 1 { *wa - 1 } else { *wa };
                if wa != *wb || la != lb {
                    return Err(format!(
                        "round {round}: degraded plan != dense survivor plan"));
                }
            }
        }

        // χ² uniformity over surviving residents; the dead node must
        // never be asked for anything.
        let mut index = std::collections::HashMap::new();
        let mut next = 0usize;
        for (w, node) in degraded.iter().enumerate() {
            for &(c, n) in node {
                for i in 0..n {
                    index.insert((w, c, i), next);
                    next += 1;
                }
            }
        }
        let mut hits = vec![0u64; tot];
        let mut prng = Rng::new(seed ^ 0x5eed);
        let rounds = 6000u64;
        for _ in 0..rounds {
            let plan = sampler.plan(&degraded, 3, &mut prng);
            for (w, picks) in &plan.requests {
                if *w == 1 {
                    return Err("plan requested from the lost peer".into());
                }
                for &(c, i) in picks {
                    hits[index[&(*w, c, i)]] += 1;
                }
            }
        }
        let chi2 = chi_square_uniform(&hits);
        // dof = tot-1 ≤ 19; 0.9999 quantile of χ²(19) ≈ 46 — allow >2x.
        if chi2 > 110.0 {
            return Err(format!("χ²={chi2} over {tot} survivors: {hits:?}"));
        }
        // The lost peer's class (1) must keep its proportional share of
        // the global picks — the renormalization may not starve it.
        let class1: u64 = degraded
            .iter()
            .enumerate()
            .flat_map(|(w, node)| node.iter().map(move |&(c, n)| (w, c, n)))
            .filter(|&(_, c, _)| c == 1)
            .map(|(w, c, n)| {
                (0..n).map(|i| hits[index[&(w, c, i)]]).sum::<u64>()
            })
            .sum();
        let expect = rounds as f64 * 3.0 * (c1a + c1b) as f64 / tot as f64;
        let ratio = class1 as f64 / expect;
        if !(0.8..=1.2).contains(&ratio) {
            return Err(format!(
                "class 1 got {class1} picks, expected ≈{expect:.0} \
                 (ratio {ratio:.3}): the lost peer's class is mis-served"));
        }
        Ok(())
    });
}

#[test]
fn every_resident_equally_likely() {
    // χ² uniformity across ALL residents of a fixed random geometry.
    forall(4, |rng| {
        let counts = vec![
            vec![(0u32, usize_in(rng, 2, 6)), (1, usize_in(rng, 2, 6))],
            vec![(0u32, usize_in(rng, 2, 6))],
            vec![(2u32, usize_in(rng, 2, 6))],
        ];
        let tot = total(&counts);
        // flat index per (node, class, idx)
        let mut index = std::collections::HashMap::new();
        let mut next = 0usize;
        for (w, node) in counts.iter().enumerate() {
            for &(c, n) in node {
                for i in 0..n {
                    index.insert((w, c, i), next);
                    next += 1;
                }
            }
        }
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let mut hits = vec![0u64; tot];
        let rounds = 6000;
        for _ in 0..rounds {
            let plan = sampler.plan(&counts, 3, &mut prng);
            for (w, picks) in &plan.requests {
                for &(c, i) in picks {
                    hits[index[&(*w, c, i)]] += 1;
                }
            }
        }
        let chi2 = chi_square_uniform(&hits);
        // dof = tot-1 ≤ 29; the 0.9999 quantile of χ²(29) ≈ 58 — allow 2x.
        if chi2 > 120.0 {
            return Err(format!("χ²={chi2} over {tot} residents: {hits:?}"));
        }
        Ok(())
    });
}
