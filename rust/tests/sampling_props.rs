//! Property tests on global-sampling invariants (paper §IV-C / DESIGN.md
//! §5): without-replacement, consolidation, location-uniformity, and
//! local-scope containment — over randomized cluster geometries.

use dcl::config::SamplingScope;
use dcl::sampling::GlobalSampler;
use dcl::testkit::prop::{forall, usize_in};
use dcl::util::rng::Rng;
use dcl::util::stats::chi_square_uniform;

/// Random per-node per-class counts; some nodes may be empty.
fn random_counts(rng: &mut Rng) -> Vec<Vec<(u32, usize)>> {
    let workers = usize_in(rng, 1, 8);
    (0..workers)
        .map(|_| {
            let classes = usize_in(rng, 0, 6);
            (0..classes)
                .map(|c| (c as u32, usize_in(rng, 1, 15)))
                .collect()
        })
        .collect()
}

fn total(counts: &[Vec<(u32, usize)>]) -> usize {
    counts.iter().flatten().map(|&(_, n)| n).sum()
}

#[test]
fn plan_size_is_min_r_total() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let r = usize_in(rng, 0, 20);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, r, &mut prng);
        let expect = r.min(total(&counts));
        if plan.total != expect {
            return Err(format!("plan.total {} != {expect}", plan.total));
        }
        let n: usize = plan.requests.iter().map(|(_, p)| p.len()).sum();
        if n != expect {
            return Err(format!("picks {n} != {expect}"));
        }
        Ok(())
    });
}

#[test]
fn no_replacement_within_plan() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let r = usize_in(rng, 0, 25);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, r, &mut prng);
        for (w, picks) in &plan.requests {
            let mut seen = std::collections::HashSet::new();
            for &(c, i) in picks {
                if !seen.insert((c, i)) {
                    return Err(format!("duplicate pick ({c},{i}) at node {w}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn consolidation_one_request_per_node() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, usize_in(rng, 1, 20), &mut prng);
        let mut nodes: Vec<usize> = plan.requests.iter().map(|(w, _)| *w).collect();
        let len = nodes.len();
        nodes.sort_unstable();
        nodes.dedup();
        if nodes.len() != len {
            return Err("multiple requests for one node".into());
        }
        if nodes.iter().any(|&w| w >= counts.len()) {
            return Err("request to unknown node".into());
        }
        Ok(())
    });
}

#[test]
fn picks_respect_class_counts() {
    forall(80, |rng| {
        let counts = random_counts(rng);
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, usize_in(rng, 1, 20), &mut prng);
        for (w, picks) in &plan.requests {
            for &(c, i) in picks {
                let n = counts[*w]
                    .iter()
                    .find(|&&(cc, _)| cc == c)
                    .map(|&(_, n)| n)
                    .unwrap_or(0);
                if i >= n {
                    return Err(format!("pick ({c},{i}) beyond count {n} on node {w}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn local_scope_stays_local() {
    forall(60, |rng| {
        let counts = random_counts(rng);
        let me = rng.below(counts.len());
        let sampler = GlobalSampler::new(me, SamplingScope::LocalOnly);
        let mut prng = Rng::new(rng.next_u64());
        let plan = sampler.plan(&counts, usize_in(rng, 1, 10), &mut prng);
        if plan.requests.iter().any(|(w, _)| *w != me) {
            return Err("local-only plan touched a remote node".into());
        }
        Ok(())
    });
}

#[test]
fn every_resident_equally_likely() {
    // χ² uniformity across ALL residents of a fixed random geometry.
    forall(4, |rng| {
        let counts = vec![
            vec![(0u32, usize_in(rng, 2, 6)), (1, usize_in(rng, 2, 6))],
            vec![(0u32, usize_in(rng, 2, 6))],
            vec![(2u32, usize_in(rng, 2, 6))],
        ];
        let tot = total(&counts);
        // flat index per (node, class, idx)
        let mut index = std::collections::HashMap::new();
        let mut next = 0usize;
        for (w, node) in counts.iter().enumerate() {
            for &(c, n) in node {
                for i in 0..n {
                    index.insert((w, c, i), next);
                    next += 1;
                }
            }
        }
        let sampler = GlobalSampler::new(0, SamplingScope::Global);
        let mut prng = Rng::new(rng.next_u64());
        let mut hits = vec![0u64; tot];
        let rounds = 6000;
        for _ in 0..rounds {
            let plan = sampler.plan(&counts, 3, &mut prng);
            for (w, picks) in &plan.requests {
                for &(c, i) in picks {
                    hits[index[&(*w, c, i)]] += 1;
                }
            }
        }
        let chi2 = chi_square_uniform(&hits);
        // dof = tot-1 ≤ 29; the 0.9999 quantile of χ²(29) ≈ 58 — allow 2x.
        if chi2 > 120.0 {
            return Err(format!("χ²={chi2} over {tot} residents: {hits:?}"));
        }
        Ok(())
    });
}
