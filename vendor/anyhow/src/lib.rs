//! Minimal, API-compatible subset of the `anyhow` crate, vendored in-tree.
//!
//! The build environments this repository targets have no crates.io access,
//! so the one external dependency the crate grew up with is reimplemented
//! here: an opaque [`Error`] holding a message and a best-effort cause
//! chain, the [`anyhow!`] / [`bail!`] macros, the [`Context`] extension
//! trait, and the `Result<T>` alias. Only the surface the `dcl` crate uses
//! is provided; semantics follow upstream anyhow (Display shows the
//! outermost message, Debug shows the chain).

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a message plus the Display renderings of the causes it
/// wrapped (outermost first is `msg`, older contexts follow in `chain`).
pub struct Error {
    inner: Box<ErrorImpl>,
}

struct ErrorImpl {
    msg: String,
    chain: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message (what `anyhow!` emits).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            inner: Box::new(ErrorImpl {
                msg: message.to_string(),
                chain: Vec::new(),
                source: None,
            }),
        }
    }

    /// Wrap this error in a new outermost context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let inner = *self.inner;
        let mut chain = Vec::with_capacity(inner.chain.len() + 1);
        chain.push(inner.msg);
        chain.extend(inner.chain);
        Error {
            inner: Box::new(ErrorImpl {
                msg: context.to_string(),
                chain,
                source: inner.source,
            }),
        }
    }

    /// The cause messages from outermost context to root cause.
    pub fn chain_messages(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.inner.msg.as_str())
            .chain(self.inner.chain.iter().map(String::as_str))
    }

    /// Root cause as a std error, when the error wrapped one.
    pub fn source(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.inner.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.inner.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner.msg)?;
        for (i, cause) in self.inner.chain.iter().enumerate() {
            if i == 0 {
                write!(f, "\n\nCaused by:")?;
            }
            write!(f, "\n    {i}: {cause}")?;
        }
        Ok(())
    }
}

// The anyhow trick: `Error` deliberately does NOT implement
// `std::error::Error`, so this blanket conversion from every std error does
// not overlap `impl From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let msg = e.to_string();
        Error {
            inner: Box::new(ErrorImpl { msg, chain: Vec::new(), source: Some(Box::new(e)) }),
        }
    }
}

/// Extension adding `.context(..)` / `.with_context(..)` to results whose
/// error converts into [`Error`] (std errors and `Error` itself, via the
/// reflexive `From`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
        let e = e.context("opening config");
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(e.chain_messages().collect::<Vec<_>>(), vec!["opening config", "gone"]);
        assert!(e.source().is_some());
    }

    #[test]
    fn macros_build_messages() {
        let x = 7;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 7 bad");
        let e = anyhow!("value {} bad", 9);
        assert_eq!(e.to_string(), "value 9 bad");
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "12x".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn with_context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root");
        }
        let e = inner().with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("root"), "{dbg}");
    }
}
